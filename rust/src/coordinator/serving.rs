//! Unified serving engine — the production request path.
//!
//! [`ServingEngine`] combines the two throughput mechanisms that previously
//! lived separately in [`super::multicore`] (batch sharding across C cores,
//! paper §IV footnote 1) and [`super::pipeline`] (per-layer stream
//! pipelining, Fig. 8) into one engine:
//!
//! * **C shards**, each a persistent per-layer pipeline: one OS thread per
//!   hardware layer owns that layer's synaptic memory and membrane state,
//!   exactly like the distributed per-layer memory that makes QUANTISENC
//!   streams overlap.
//! * **Bounded channels** everywhere: admission blocks when the engine is
//!   saturated (`queue_depth` messages per stage), which is the
//!   backpressure story — a flooded engine slows producers instead of
//!   buffering unboundedly.
//! * **Deterministic, in-order results**: single-sample mode assigns
//!   streams round-robin (sample *i* → shard *i mod C*); lane mode packs
//!   consecutive samples into groups and dispatches each group to the
//!   shard with the least cumulative dispatched work — a deterministic
//!   work-stealing schedule (a pure function of the op stream, never of
//!   thread timing). Within a shard the stage chain is FIFO and the
//!   feeder records every assignment, so the drainer merges shard outputs
//!   back into submission order. Every stream is settled (membranes
//!   reset) between samples, so results are bit-for-bit identical to a
//!   sequential [`crate::hdl::Core`] run — asserted in tests and in
//!   `benches/bench_serving.rs`.
//! * **Live reconfiguration**: the engine is *software-defined* after
//!   deployment. A [`ControlPlane`] handle (see
//!   [`ServingEngine::control_plane`]) applies cfg_in register programs and
//!   wt_in packed weight swaps while traffic is flowing: accepted programs
//!   ride the same bounded stage channels as epoch-tagged
//!   `StageMsg::Reconfig` control messages, broadcast to every shard at a
//!   sample boundary, so each sample is processed entirely under one config
//!   epoch and each [`StreamResult`] reports the epoch it was computed
//!   under. [`ServingEngine::run_session`] additionally schedules
//!   reconfigurations *in-band*, at exact positions in the request stream.
//!
//! * **Zero-alloc streaming**: stage channels carry bit-packed
//!   [`SpikePlane`]s recycled through buffer pools — each stage reuses the
//!   plane it consumed as a future output buffer, the collector returns
//!   drained planes to an engine-wide [`PlanePool`] the feeder draws from,
//!   and the pool is pre-filled at construction to cover the engine's
//!   maximum in-flight footprint, so the steady-state streaming path
//!   performs **zero plane allocations** (debug-asserted on every batch
//!   via [`PlanePool::misses`]).
//! * **Lane batching** ([`ServingOptions::lane_width`] > 1): the feeder
//!   packs up to 64 consecutive samples into one group, sent to its shard
//!   as one [`SpikeMatrix`] per timestep; every stage steps all lanes at
//!   once
//!   ([`crate::hdl::Layer::step_lanes`]) with each synaptic row fetched
//!   **once** per firing line and every channel hop amortized across the
//!   whole group, lanes of ragged batches are masked out as their streams
//!   end, and the collector demuxes lane results back into in-order
//!   [`StreamResult`]s — bit-identical (counts, epochs, per-stream
//!   activity ledgers) to the single-sample path, which remains the
//!   `lane_width == 1` fallback and conformance oracle. Matrices recycle
//!   through a pre-filled [`MatrixPool`] with the same zero-alloc
//!   contract. With [`ServingOptions::sparse_cutoff`] set, samples whose
//!   input firing density falls below the cutoff skip lane packing and
//!   stream down the single-sample path instead, where the layers'
//!   quiescence fast path elides most neuron work — dense traffic pays
//!   the batched costs, near-silent traffic does not.
//!
//! The per-stage loop (`stage_loop`) and the spike-count collector
//! (`collector_loop`) are shared with [`super::pipeline::run_pipelined`],
//! which is now a thin scoped-thread wrapper over the same primitives.

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::Result;

use crate::config::registers::RegisterFile;
use crate::config::ModelConfig;
use crate::datasets::Sample;
use crate::hdl::core::argmax;
use crate::hdl::layer::Layer;
use crate::hdl::spikes::{MatrixPool, PlanePool, SpikeMatrix, SpikePlane};
use crate::hdl::ActivityStats;

use super::control::{ControlPlane, ControlShared, ReconfigProgram};
use super::interface::BusStats;

pub use super::pipeline::StreamResult;

/// Typed failure of the serving data path.
///
/// The variant that matters operationally is [`WorkerPanicked`]
/// (`ServingError::WorkerPanicked`): a stage/feeder/collector thread
/// panicking used to take down the whole process via
/// `join().expect(...)` — fatal once many tenants share one engine
/// behind the network front door. A panic now surfaces as this error
/// (carrying the panic payload's message), the engine shuts itself down,
/// and the process — and every other tenant's connection — stays alive.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServingError {
    /// A worker thread panicked; `worker` names it and `message` is the
    /// stringified panic payload. The engine is shut down but droppable.
    WorkerPanicked { worker: String, message: String },
    /// The engine was shut down (or poisoned and self-shut-down); no
    /// further batches or snapshots are possible. Submitting used to hit
    /// an `expect` on the closed stage channel and panic the caller —
    /// now it is an ordinary, typed refusal.
    ShutDown,
}

impl std::fmt::Display for ServingError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServingError::WorkerPanicked { worker, message } => {
                write!(f, "serving {worker} panicked: {message}")
            }
            ServingError::ShutDown => {
                write!(f, "serving engine is shut down; rebuild or restore it")
            }
        }
    }
}

impl std::error::Error for ServingError {}

/// Stringify a `JoinHandle::join` panic payload (panics carry `&str` or
/// `String` in practice; anything else is reported opaquely).
pub(crate) fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

/// Message flowing down a shard's stage chain: one timestep's bit-packed
/// spike plane (a recycled pool buffer — see the module docs), the Fig.-8
/// settle marker that ends a stream (accumulating the stream's activity
/// ledger as it passes each stage), their lane-batched twins (one
/// [`SpikeMatrix`] carrying up to 64 samples' spikes per timestep, one
/// group flush carrying the per-lane ledgers and stream ids), or an
/// epoch-tagged cfg_in/wt_in reconfiguration broadcast by the control
/// plane.
pub(crate) enum StageMsg {
    Step { stream: usize, plane: SpikePlane },
    Flush { stream: usize, stats: ActivityStats },
    /// One timestep of a lane group: `active` masks the lanes still
    /// streaming (ragged stream lengths), so per-lane ledgers stay
    /// bit-identical to single-sample runs.
    StepLanes { matrix: SpikeMatrix, active: u64 },
    /// End of a lane group: `streams[l]` is lane `l`'s stream id;
    /// `stats[l]` accumulates lane `l`'s activity as the marker passes
    /// each stage (the lane twin of `Flush`).
    FlushLanes { streams: Vec<usize>, stats: Vec<ActivityStats> },
    Reconfig { epoch: u64, program: Arc<ReconfigProgram> },
    /// Connectome snapshot fence: each stage writes its full state
    /// (registers, packed weights, neuron banks) to `reply` and forwards
    /// the fence downstream. Because it rides the same FIFO as the data,
    /// the export is automatically taken at a sample-group boundary —
    /// nothing in flight, nothing drained.
    Export { reply: std::sync::mpsc::Sender<LayerExport> },
    /// Connectome restore: each stage applies its entry of `states`
    /// (weights + neuron banks; registers were seeded at construction),
    /// acks on `reply`, and forwards. Payloads are validated against the
    /// engine geometry *before* this message is sent, so stage-side
    /// application is infallible — the Reconfig precedent.
    Import { states: Arc<Vec<LayerExport>>, reply: std::sync::mpsc::Sender<()> },
}

/// Alias local to the stage machinery: the per-(shard, layer) state
/// section of a [`Connectome`](super::connectome::Connectome).
pub(crate) type LayerExport = super::connectome::LayerState;

/// Body of one pipeline stage: owns hardware layer `layer_idx`, transforms
/// spike vectors, resets its membranes at every stream boundary, and applies
/// the slice of each control-plane program that addresses it (all register
/// writes — the decoder registers are core-global — plus its own layer's
/// weight payload). Control messages are applied *between* streams by
/// construction: they arrive through the same FIFO as the data, so every
/// stream is processed entirely under one config epoch. Returns when the
/// input channel closes or the downstream consumer disappears.
pub(crate) fn stage_loop(
    layer_idx: usize,
    mut layer: Layer,
    mut regs: RegisterFile,
    rx: Receiver<StageMsg>,
    tx: SyncSender<StageMsg>,
    mut pool: Vec<SpikePlane>,
    mut mat_pool: Vec<SpikeMatrix>,
) {
    // Activity accumulated by this stage for the stream in flight.
    let mut acc = ActivityStats::default();
    // Lane-batched twins: per-lane accumulators for the group in flight
    // and the per-step scratch `Layer::step_lanes` writes into (sized on
    // first use; the engine keeps the lane width constant).
    let mut acc_lanes: Vec<ActivityStats> = Vec::new();
    let mut lane_scratch: Vec<ActivityStats> = Vec::new();
    for msg in rx {
        match msg {
            StageMsg::Step { stream, plane } => {
                // Output buffer from the stage-local free list; the consumed
                // input plane is recycled into the same list below, so a
                // pre-filled stage never allocates (and each plane's word
                // storage settles at max(fan_in, neurons) words).
                let mut out = pool.pop().unwrap_or_default();
                let mut st = layer.step_plane(&plane, &mut out, &regs);
                if layer_idx != 0 {
                    // One spk_clk edge per *core* timestep, not per layer —
                    // matches `Core::step`'s accounting bit-for-bit.
                    st.spk_steps = 0;
                }
                acc.add(&st);
                pool.push(plane);
                if tx.send(StageMsg::Step { stream, plane: out }).is_err() {
                    return;
                }
            }
            StageMsg::Flush { stream, stats: mut upstream } => {
                // Fig. 8 settle: membranes back to rest between streams.
                layer.reset();
                upstream.add(&acc);
                acc = ActivityStats::default();
                if tx.send(StageMsg::Flush { stream, stats: upstream }).is_err() {
                    return;
                }
            }
            StageMsg::StepLanes { matrix, active } => {
                let lanes = matrix.lanes();
                if acc_lanes.len() != lanes {
                    acc_lanes.resize(lanes, ActivityStats::default());
                    lane_scratch.resize(lanes, ActivityStats::default());
                }
                let mut out = mat_pool.pop().unwrap_or_default();
                layer.step_lanes(&matrix, &mut out, &regs, active, &mut lane_scratch);
                for (l, st) in lane_scratch.iter_mut().enumerate() {
                    if layer_idx != 0 {
                        // One spk_clk edge per core timestep per lane.
                        st.spk_steps = 0;
                    }
                    acc_lanes[l].add(st);
                }
                mat_pool.push(matrix);
                if tx.send(StageMsg::StepLanes { matrix: out, active }).is_err() {
                    return;
                }
            }
            StageMsg::FlushLanes { streams, stats: mut upstream } => {
                // Settle every lane's membranes between groups; fold this
                // stage's per-lane ledgers into the marker (zip tolerates a
                // ragged final group shorter than the lane width, and a
                // zero-step group that never sized the accumulators).
                layer.reset();
                for (st, lane_acc) in upstream.iter_mut().zip(&acc_lanes) {
                    st.add(lane_acc);
                }
                for lane_acc in acc_lanes.iter_mut() {
                    *lane_acc = ActivityStats::default();
                }
                if tx.send(StageMsg::FlushLanes { streams, stats: upstream }).is_err() {
                    return;
                }
            }
            StageMsg::Reconfig { epoch, program } => {
                if program.chaos_panic_stage == Some(layer_idx) {
                    // Fault-injection hook (see ReconfigProgram): prove a
                    // worker panic becomes ServingError::WorkerPanicked,
                    // not a process abort.
                    panic!("chaos program panicked stage {layer_idx}");
                }
                // Programs are validated by the control plane before they
                // are admitted, so stage-side application is infallible —
                // a half-applied config cannot exist.
                regs.apply_program(&program.cfg).expect("program validated by control plane");
                for (k, payload) in &program.weights {
                    if *k == layer_idx {
                        layer
                            .load_packed(payload)
                            .expect("payload validated by control plane");
                    }
                }
                if tx.send(StageMsg::Reconfig { epoch, program }).is_err() {
                    return;
                }
            }
            StageMsg::Export { reply } => {
                let (lanes, lane_vmem, lane_refcnt) = layer.lane_state();
                // Send errors mean the snapshotter gave up (timeout) —
                // the fence still flows downstream so later stages drain.
                let _ = reply.send(LayerExport {
                    regs: regs.vector(),
                    weights: layer.memory().packed().to_vec(),
                    vmem: layer.vmem_slice().to_vec(),
                    refcnt: layer.refcnt_slice().to_vec(),
                    lanes: lanes as u16,
                    lane_vmem,
                    lane_refcnt,
                });
                if tx.send(StageMsg::Export { reply }).is_err() {
                    return;
                }
            }
            StageMsg::Import { states, reply } => {
                let st = &states[layer_idx];
                layer.load_packed(&st.weights).expect("payload validated before import");
                layer.restore_state(&st.vmem, &st.refcnt);
                layer.restore_lanes(st.lanes as usize, &st.lane_vmem, &st.lane_refcnt);
                let _ = reply.send(());
                if tx.send(StageMsg::Import { states, reply }).is_err() {
                    return;
                }
            }
        }
    }
}

/// Send one lane group down a shard's chain: `t_max` lane-matrix steps
/// (lane `l` = `group[l]`, masked out once its stream ends — ragged
/// lengths never leak across lanes) followed by the group flush carrying
/// the lanes' stream ids. Matrices come from the engine pool and are
/// always `lane_width` wide, so a ragged final group reuses the same
/// stage lane banks (its high lanes simply never go active).
fn feed_group(
    tx: &SyncSender<StageMsg>,
    streams: &mut Vec<usize>,
    group: &mut Vec<&Sample>,
    matrix_pool: &MatrixPool,
    lane_width: usize,
    inputs: usize,
) -> Result<()> {
    if group.is_empty() {
        return Ok(());
    }
    let dead = || anyhow::anyhow!("serving shard died");
    let t_max = group.iter().map(|s| s.t_steps).max().unwrap_or(0);
    for t in 0..t_max {
        let mut matrix = matrix_pool.take();
        matrix.resize_clear(inputs, lane_width);
        let mut active = 0u64;
        for (l, s) in group.iter().enumerate() {
            if t < s.t_steps {
                matrix.load_lane_bytes(l, s.step(t));
                active |= 1 << l;
            }
        }
        tx.send(StageMsg::StepLanes { matrix, active }).map_err(|_| dead())?;
    }
    tx.send(StageMsg::FlushLanes {
        streams: std::mem::take(streams),
        stats: vec![ActivityStats::default(); group.len()],
    })
    .map_err(|_| dead())?;
    group.clear();
    Ok(())
}

/// Index of the shard with the least cumulative dispatched work, lowest
/// index on ties (`min_by_key` returns the *first* minimum). The choice is
/// a pure function of the op stream, so identical sessions yield identical
/// shard assignments run-to-run — which keeps per-shard lane-bank shapes,
/// and therefore connectome snapshots, reproducible.
fn least_loaded(load: &[u64]) -> usize {
    load.iter().enumerate().min_by_key(|&(_, &c)| c).map(|(i, _)| i).unwrap_or(0)
}

/// Dispatch the pending lane group (possibly partial) to the least-loaded
/// shard and record the assignment for the drainer.
///
/// This is the serving engine's work-stealing scheduler in deterministic
/// form: instead of idle stage threads racing to pop a shared deque
/// (which would make shard assignment — and with it lane-bank widths and
/// connectome snapshots — depend on thread timing), the feeder tracks the
/// cumulative step-cost dispatched to each shard and hands every ready
/// group to the shard that has received the least. An idle shard thereby
/// takes exactly the group a hot shard would otherwise have queued, while
/// the schedule stays a pure function of the op stream. Groups pack
/// **consecutive** stream ids, so dispatch order equals stream order and
/// the drainer's per-record in-order recv argument holds.
///
/// Called when a group fills, before any reconfiguration broadcast (epoch
/// boundaries land between groups), before a sparse-fallback single (so
/// results stay in submission order), and at end of session.
fn dispatch_group(
    pending: &mut (Vec<usize>, Vec<&Sample>),
    senders: &[SyncSender<StageMsg>],
    load: &mut [u64],
    assign: &std::sync::mpsc::Sender<(usize, usize)>,
    matrix_pool: &MatrixPool,
    lane_width: usize,
    inputs: usize,
) -> Result<()> {
    let (streams, group) = pending;
    if group.is_empty() {
        return Ok(());
    }
    let shard = least_loaded(load);
    // Cost model: one StepLanes message per timestep plus the FlushLanes
    // marker — proportional to the stage work the group induces.
    let t_max = group.iter().map(|s| s.t_steps).max().unwrap_or(0) as u64;
    load[shard] += t_max + 1;
    // The record channel is unbounded and the drainer holds its receiver
    // until the session scope ends, so this send cannot block; a closed
    // receiver only happens while the scope is already unwinding.
    let _ = assign.send((shard, group.len()));
    feed_group(&senders[shard], streams, group, matrix_pool, lane_width, inputs)
}

/// Body of the terminal collector: accumulates output-layer spike counts per
/// stream, tracks the config epoch announced by [`StageMsg::Reconfig`]
/// markers, and emits one [`StreamResult`] per `Flush` (carrying the epoch
/// and the full activity ledger the stages accumulated). Lane-batched
/// groups are **demuxed** here: per-lane spike counters accumulate from
/// each output [`SpikeMatrix`]'s lane-words, and a `FlushLanes` marker
/// emits one in-order result per lane. Drained planes/matrices are
/// returned to their pools, closing the feeder → stages → collector
/// recycle loop. `emit` returning false stops the loop (downstream gone).
pub(crate) fn collector_loop<F: FnMut(StreamResult) -> bool>(
    n_out: usize,
    rx: Receiver<StageMsg>,
    pool: Arc<PlanePool>,
    mat_pool: Arc<MatrixPool>,
    mut emit: F,
) {
    let mut counts = vec![0u32; n_out];
    let mut spikes_total = 0u64;
    // Lane demux state, sized on the first lane-batched step.
    let mut lane_counts: Vec<Vec<u32>> = Vec::new();
    let mut lane_spikes: Vec<u64> = Vec::new();
    let mut epoch = 0u64;
    for msg in rx {
        match msg {
            StageMsg::Step { plane, .. } => {
                debug_assert_eq!(plane.len(), n_out, "output plane arity");
                for j in plane.iter_ones() {
                    counts[j] += 1;
                    spikes_total += 1;
                }
                pool.put(plane);
            }
            StageMsg::Flush { stream, stats } => {
                let result = StreamResult {
                    stream_id: stream,
                    prediction: argmax(&counts),
                    counts: std::mem::replace(&mut counts, vec![0u32; n_out]),
                    spikes_total,
                    epoch,
                    stats,
                };
                spikes_total = 0;
                if !emit(result) {
                    return;
                }
            }
            StageMsg::StepLanes { matrix, .. } => {
                debug_assert_eq!(matrix.lines(), n_out, "output matrix arity");
                if lane_counts.len() != matrix.lanes() {
                    lane_counts.resize(matrix.lanes(), vec![0u32; n_out]);
                    lane_spikes.resize(matrix.lanes(), 0);
                }
                for (j, &word) in matrix.words().iter().enumerate() {
                    let mut bits = word;
                    while bits != 0 {
                        let l = bits.trailing_zeros() as usize;
                        bits &= bits - 1;
                        lane_counts[l][j] += 1;
                        lane_spikes[l] += 1;
                    }
                }
                mat_pool.put(matrix);
            }
            StageMsg::FlushLanes { streams, stats } => {
                for (l, (stream, lane_stats)) in streams.into_iter().zip(stats).enumerate() {
                    // A zero-step group may never have sized the demux
                    // state; such lanes have all-zero counts.
                    let counts = if l < lane_counts.len() {
                        std::mem::replace(&mut lane_counts[l], vec![0u32; n_out])
                    } else {
                        vec![0u32; n_out]
                    };
                    let spikes_total =
                        if l < lane_spikes.len() { std::mem::take(&mut lane_spikes[l]) } else { 0 };
                    let result = StreamResult {
                        stream_id: stream,
                        prediction: argmax(&counts),
                        counts,
                        spikes_total,
                        epoch,
                        stats: lane_stats,
                    };
                    if !emit(result) {
                        return;
                    }
                }
            }
            StageMsg::Reconfig { epoch: e, .. } => {
                epoch = e;
            }
            // Snapshot fences terminate here: every stage already exported
            // (or imported) by the time the marker reaches the collector.
            StageMsg::Export { .. } | StageMsg::Import { .. } => {}
        }
    }
}

/// Build one shard's programmed layer chain (shared with
/// [`super::pipeline::run_pipelined`]). Weights arrive as the artifact
/// store's dense matrices and are scattered into each layer's
/// topology-aware store — a Gaussian/one-to-one shard only allocates the
/// synapses its topology instantiates.
pub(crate) fn build_layers(config: &ModelConfig, weights: &[Vec<i32>]) -> Result<Vec<Layer>> {
    anyhow::ensure!(weights.len() == config.num_layers(), "weights arity");
    let mut layers: Vec<Layer> = config
        .layers()
        .iter()
        .map(|l| Layer::new(l, config.qspec, config.mem))
        .collect();
    for (layer, w) in layers.iter_mut().zip(weights) {
        layer.memory_mut().load_dense(w)?;
    }
    Ok(layers)
}

/// Engine tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct ServingOptions {
    /// Number of sharded cores C (each shard pipelines its layers).
    pub cores: usize,
    /// Bounded-channel capacity per stage — the admission/backpressure
    /// window, in messages (one message ≈ one timestep of one stream,
    /// or of one whole lane group in batched mode).
    pub queue_depth: usize,
    /// Samples stepped concurrently per shard message (1..=64). At 1 the
    /// engine runs the single-sample packed path; above 1 the feeder packs
    /// **consecutive** samples into lane groups and dispatches each ready
    /// group to the least-loaded shard (see [`ServingEngine::run_session`]),
    /// so every synaptic row fetch and every channel hop is amortized
    /// across the batch. Results are bit-identical either way.
    pub lane_width: usize,
    /// Firing-rate-aware admission policy for lane-batched engines: a
    /// sample whose input spike density (`nnz / (t_steps × inputs)`) is
    /// **below** this cutoff bypasses lane packing and is streamed down the
    /// single-sample packed path, whose per-neuron quiescence fast path
    /// does near-zero work on silence — dense-batch costs are only paid by
    /// streams dense enough to amortize them. `None` (default) packs
    /// everything. Routing never changes results (both paths are
    /// bit-identical); an out-of-order hazard is avoided by flushing the
    /// pending group before a sparse sample is dispatched.
    pub sparse_cutoff: Option<f64>,
}

impl Default for ServingOptions {
    fn default() -> Self {
        ServingOptions { cores: 2, queue_depth: 64, lane_width: 1, sparse_cutoff: None }
    }
}

impl ServingOptions {
    pub fn with_cores(cores: usize) -> ServingOptions {
        ServingOptions { cores, ..Default::default() }
    }

    /// Lane-batched engine: C shards × `lane_width` samples per step.
    pub fn with_lanes(cores: usize, lane_width: usize) -> ServingOptions {
        ServingOptions { cores, lane_width, ..Default::default() }
    }

    /// Builder: set the sparse-stream fallback cutoff (see
    /// [`ServingOptions::sparse_cutoff`]).
    pub fn sparse_cutoff(mut self, cutoff: f64) -> ServingOptions {
        self.sparse_cutoff = Some(cutoff);
        self
    }
}

/// One operation in a [`ServingEngine::run_session`] request stream: admit
/// a sample, or reconfigure the engine *at exactly this point* in the
/// stream (all earlier samples finish under the old epoch, all later ones
/// run under the new one — deterministically, unlike the asynchronous
/// [`ControlPlane::apply`] whose boundary depends on arrival time).
pub enum SessionOp<'a> {
    Submit(&'a Sample),
    Reconfig(ReconfigProgram),
}

struct Shard {
    in_tx: Option<SyncSender<StageMsg>>,
    out_rx: Receiver<StreamResult>,
    threads: Vec<JoinHandle<()>>,
}

/// C sharded, per-layer-pipelined QUANTISENC cores behind one batched,
/// backpressured, order-preserving, **run-time reprogrammable** API.
///
/// ```
/// use quantisenc::config::registers::RegisterFile;
/// use quantisenc::config::ModelConfig;
/// use quantisenc::coordinator::serving::{ServingEngine, ServingOptions};
/// use quantisenc::datasets::Sample;
/// use quantisenc::fixed::Q5_3;
///
/// let cfg = ModelConfig::parse_arch("4x3x2", Q5_3)?;
/// let weights = vec![vec![4; 12], vec![4; 6]];
/// let regs = RegisterFile::new(Q5_3);
/// let mut engine = ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_cores(2))?;
///
/// let samples: Vec<Sample> = (0..4)
///     .map(|_| Sample { spikes: vec![1; 8], t_steps: 2, inputs: 4, label: 0 })
///     .collect();
/// let results = engine.run_batch(&samples)?;
/// assert_eq!(results.len(), 4);
/// // Results come back in submission order, tagged with the config epoch
/// // (0 = the construction-time configuration).
/// assert!(results.iter().enumerate().all(|(i, r)| r.stream_id == i && r.epoch == 0));
/// # Ok::<(), anyhow::Error>(())
/// ```
pub struct ServingEngine {
    shards: Vec<Shard>,
    /// The deployed architecture — kept so snapshots are self-describing
    /// and a restored engine can be rebuilt without the original artifact.
    config: ModelConfig,
    inputs: usize,
    outputs: usize,
    /// Physical synaptic storage words per shard (topology-aware stores).
    synapse_words: usize,
    /// Control-plane state shared with every [`ControlPlane`] handle.
    control: Arc<ControlShared>,
    /// Engine-wide recycled [`SpikePlane`] free list: the feeder draws
    /// input planes here, the collectors return drained output planes.
    /// Pre-filled to the maximum in-flight footprint, so steady-state
    /// streaming allocates nothing ([`ServingEngine::plane_pool_misses`]).
    plane_pool: Arc<PlanePool>,
    /// The lane-batched twin of `plane_pool`: recycled [`SpikeMatrix`]
    /// buffers for `lane_width > 1` engines, pre-filled to the same
    /// in-flight bound ([`ServingEngine::matrix_pool_misses`]).
    matrix_pool: Arc<MatrixPool>,
    /// Samples packed per lane group (1 = single-sample path).
    lane_width: usize,
    /// Firing-density cutoff below which a sample bypasses lane packing
    /// and streams down the single-sample quiescence fast path
    /// ([`ServingOptions::sparse_cutoff`]).
    sparse_cutoff: Option<f64>,
    submitted: u64,
    completed: u64,
    /// Cumulative [`ActivityStats`] over every completed stream — the
    /// engine-lifetime activity ledger a connectome snapshot carries.
    activity: ActivityStats,
    /// Set when a batch failed mid-flight: in-flight state is then
    /// indeterminate, so the engine refuses further batches (rebuild it).
    poisoned: bool,
}

impl ServingEngine {
    /// Build C identical programmed shards (persistent stage threads spin up
    /// immediately and idle on their channels).
    pub fn new(
        config: &ModelConfig,
        weights: &[Vec<i32>],
        regs: &RegisterFile,
        options: ServingOptions,
    ) -> Result<ServingEngine> {
        anyhow::ensure!(options.cores >= 1, "need at least one core");
        anyhow::ensure!(options.queue_depth >= 1, "queue depth must be positive");
        anyhow::ensure!(
            (1..=64).contains(&options.lane_width),
            "lane width must be 1..=64 (one bit per sample in a u64 lane word)"
        );
        let lanes = options.lane_width;
        let n_out = config.outputs();
        let max_width = config.sizes().iter().copied().max().unwrap_or(1);
        // Upper bound on planes (or lane matrices, in batched mode)
        // simultaneously *outside* the shared pool, per shard: every
        // bounded-channel slot of the K+1 stage channels can hold one Step
        // buffer, each of the K stages holds at most two in hand (input
        // being processed + output just popped), plus one each in the
        // feeder's and collector's hands. Pre-filling past this bound means
        // the pool never allocates in steady state — the zero-alloc
        // invariant `run_session` debug-asserts. Only the active mode's
        // pool is pre-filled (the other is never drawn from).
        let per_shard = (config.num_layers() + 1) * options.queue_depth
            + 2 * config.num_layers()
            + 4;
        // The sparse-stream fallback routes below-cutoff samples down the
        // single-sample plane path even in lane mode, so such engines
        // pre-fill both pools (the zero-alloc invariant covers both).
        let wants_planes = lanes == 1 || options.sparse_cutoff.is_some();
        let plane_pool = Arc::new(if wants_planes {
            PlanePool::prefilled(options.cores * per_shard, max_width)
        } else {
            PlanePool::new()
        });
        let matrix_pool = Arc::new(if lanes > 1 {
            MatrixPool::prefilled(options.cores * per_shard, max_width)
        } else {
            MatrixPool::new()
        });
        let mut shards = Vec::with_capacity(options.cores);
        let mut synapse_words = 0usize;
        let mut packed_sizes: Vec<usize> = Vec::new();
        for shard_idx in 0..options.cores {
            let layers = build_layers(config, weights)?;
            if shard_idx == 0 {
                // Shards are identical; measure the footprint once. The
                // per-layer word counts double as the control plane's
                // wt_in payload-size contract.
                packed_sizes = layers.iter().map(|l| l.memory().synapses()).collect();
                synapse_words = packed_sizes.iter().sum();
            }
            let mut threads = Vec::with_capacity(layers.len() + 1);
            let (first_tx, mut chain_rx) = sync_channel::<StageMsg>(options.queue_depth);
            for (layer_idx, layer) in layers.into_iter().enumerate() {
                let (tx, next_rx) = sync_channel::<StageMsg>(options.queue_depth);
                let stage_regs = regs.clone();
                let rx = std::mem::replace(&mut chain_rx, next_rx);
                // Two pre-sized buffers per stage-local free list cover the
                // one output buffer a stage ever needs in hand (planes on
                // the single-sample path, lane matrices in batched mode).
                // A sparse-fallback engine mixes both message kinds, so its
                // stages carry both free lists.
                let stage_pool = if wants_planes {
                    vec![
                        SpikePlane::with_line_capacity(max_width),
                        SpikePlane::with_line_capacity(max_width),
                    ]
                } else {
                    Vec::new()
                };
                let stage_mats = if lanes > 1 {
                    vec![
                        SpikeMatrix::with_line_capacity(max_width),
                        SpikeMatrix::with_line_capacity(max_width),
                    ]
                } else {
                    Vec::new()
                };
                threads.push(std::thread::spawn(move || {
                    stage_loop(layer_idx, layer, stage_regs, rx, tx, stage_pool, stage_mats)
                }));
            }
            // In lane mode a single FlushLanes emits up to lane_width
            // results at once; the result channel must absorb a whole
            // group so the collector never wedges mid-flush.
            let (out_tx, out_rx) =
                sync_channel::<StreamResult>(options.queue_depth.max(lanes) + lanes);
            let collector_rx = chain_rx;
            let collector_pool = plane_pool.clone();
            let collector_mats = matrix_pool.clone();
            threads.push(std::thread::spawn(move || {
                collector_loop(n_out, collector_rx, collector_pool, collector_mats, |r| {
                    out_tx.send(r).is_ok()
                })
            }));
            shards.push(Shard { in_tx: Some(first_tx), out_rx, threads });
        }
        let control = Arc::new(ControlShared::new(regs.clone(), packed_sizes, options.cores));
        Ok(ServingEngine {
            shards,
            config: config.clone(),
            inputs: config.inputs(),
            outputs: n_out,
            synapse_words,
            control,
            plane_pool,
            matrix_pool,
            lane_width: lanes,
            sparse_cutoff: options.sparse_cutoff,
            submitted: 0,
            completed: 0,
            activity: ActivityStats::default(),
            poisoned: false,
        })
    }

    /// Samples stepped per shard message (1 = single-sample path).
    pub fn lane_width(&self) -> usize {
        self.lane_width
    }

    /// The firing-density cutoff for the sparse-stream fallback, if one
    /// was configured ([`ServingOptions::sparse_cutoff`]).
    pub fn sparse_cutoff(&self) -> Option<f64> {
        self.sparse_cutoff
    }

    /// Spike lines of the input layer (spk_in width) — the sample width
    /// every admitted stream must match.
    pub fn inputs(&self) -> usize {
        self.inputs
    }

    /// Neurons of the output layer (spk_out width) — the arity of every
    /// [`StreamResult::counts`].
    pub fn outputs(&self) -> usize {
        self.outputs
    }

    pub fn num_cores(&self) -> usize {
        self.shards.len()
    }

    /// Physical synaptic storage words per shard — measured from the
    /// topology-aware stores, so a Gaussian/one-to-one engine reports its
    /// actual (sparse) memory footprint, not the dense M×N size.
    pub fn synapse_words_per_shard(&self) -> usize {
        self.synapse_words
    }

    /// Requests accepted / completed over the engine's lifetime.
    pub fn stats(&self) -> (u64, u64) {
        (self.submitted, self.completed)
    }

    /// Times the streaming path had to allocate a spike plane because the
    /// recycled-buffer pool was dry. Stays 0 for the engine's whole
    /// lifetime (the pool is pre-filled past the in-flight bound); the
    /// engine debug-asserts this after every batch.
    pub fn plane_pool_misses(&self) -> u64 {
        self.plane_pool.misses()
    }

    /// Lane-batched twin of [`ServingEngine::plane_pool_misses`]: times the
    /// batched streaming path had to allocate a [`SpikeMatrix`] because the
    /// recycled-buffer pool was dry. Stays 0 for the engine's lifetime;
    /// debug-asserted after every batch.
    pub fn matrix_pool_misses(&self) -> u64 {
        self.matrix_pool.misses()
    }

    /// A cloneable, thread-safe [`ControlPlane`] handle for reprogramming
    /// this engine while it serves — see [`super::control`] for the epoch
    /// and validation semantics.
    pub fn control_plane(&self) -> ControlPlane {
        ControlPlane::from_shared(self.control.clone())
    }

    /// The engine's AXI transaction ledger ([`BusStats`], §IV bus model):
    /// cfg_in/wt_in control beats charged by the control plane (per shard)
    /// and spk_in/spk_out data beats metered by admission and drain — one
    /// ledger for control and data traffic.
    pub fn bus(&self) -> BusStats {
        self.control.bus()
    }

    /// The config epoch the *next* admitted sample will be served under
    /// (0 until the first accepted reconfiguration).
    pub fn epoch(&self) -> u64 {
        self.control.epoch()
    }

    /// Serve a batch: admission feeds the shards under backpressure
    /// (round-robin in single-sample mode, least-loaded lane groups in
    /// lane mode) while results are drained concurrently; returns one
    /// result per sample, in submission order, bit-identical to a
    /// sequential core. Control-plane programs admitted via
    /// [`ControlPlane::apply`] are broadcast at sample boundaries of this
    /// feed (and before the first sample).
    pub fn run_batch(&mut self, samples: &[Sample]) -> Result<Vec<StreamResult>> {
        let ops: Vec<SessionOp> = samples.iter().map(SessionOp::Submit).collect();
        self.run_session(&ops)
    }

    /// Serve a request stream that interleaves samples with in-band
    /// reconfigurations. Each [`SessionOp::Reconfig`] takes effect at
    /// exactly its position: samples before it complete under the previous
    /// epoch, samples after it under the new one, with no drain in between
    /// — the control message simply flows down the same bounded channels
    /// behind the last sample's data. Returns one result per
    /// [`SessionOp::Submit`], in submission order, each tagged with its
    /// epoch.
    ///
    /// In-band programs are validated up front; an invalid program fails
    /// the call before any sample is admitted (the engine stays healthy).
    pub fn run_session(&mut self, ops: &[SessionOp]) -> Result<Vec<StreamResult>> {
        anyhow::ensure!(
            !self.poisoned,
            "serving engine poisoned by an earlier failed batch; build a new engine"
        );
        let mut n_samples = 0usize;
        for op in ops {
            match op {
                SessionOp::Submit(s) => {
                    anyhow::ensure!(
                        s.inputs == self.inputs,
                        "sample width {} does not match engine input layer {}",
                        s.inputs,
                        self.inputs
                    );
                    n_samples += 1;
                }
                SessionOp::Reconfig(program) => {
                    self.control.validate(program)?;
                }
            }
        }
        let n_cores = self.shards.len();
        // A shut-down engine has dropped its stage senders; submitting to
        // it is a typed, recoverable refusal — not an `expect` panic.
        let mut senders: Vec<SyncSender<StageMsg>> = Vec::with_capacity(n_cores);
        for shard in &self.shards {
            match &shard.in_tx {
                Some(tx) => senders.push(tx.clone()),
                None => return Err(ServingError::ShutDown.into()),
            }
        }
        let control = self.control.clone();
        let plane_pool = self.plane_pool.clone();
        let matrix_pool = self.matrix_pool.clone();
        let lane_width = self.lane_width;
        let sparse_cutoff = self.sparse_cutoff;
        let inputs = self.inputs;
        let pool_misses_before = self.plane_pool.misses();
        let mat_misses_before = self.matrix_pool.misses();
        // Assignment records (shard, n_results): the feeder appends one per
        // dispatched unit in stream order; the drainer follows them to know
        // which shard's output queue holds the next in-order results.
        // Unbounded — records are tiny and the feeder must never block on
        // bookkeeping while holding backpressured data channels.
        let (assign_tx, assign_rx) = std::sync::mpsc::channel::<(usize, usize)>();

        let results = std::thread::scope(|scope| -> Result<Vec<StreamResult>> {
            // Feeder: streams every sample to a shard (blocking on the
            // bounded channels = admission control) and broadcasts control
            // programs to *all* shards at sample boundaries, so the FIFO
            // position of a Reconfig is identical in every chain. In
            // lane-batched mode (`lane_width > 1`) consecutive samples are
            // packed into one lane group sent as a SpikeMatrix per
            // timestep, and each ready group goes to the shard with the
            // least cumulative dispatched work (see [`dispatch_group`]);
            // partial groups are flushed before any reconfiguration
            // broadcast, so epoch semantics are unchanged. Every dispatch
            // appends an assignment record the drainer follows.
            let feeder = scope.spawn(move || -> Result<()> {
                let dead = || anyhow::anyhow!("serving shard died");
                let broadcast = |epoch: u64, program: &Arc<ReconfigProgram>| -> Result<()> {
                    for tx in &senders {
                        tx.send(StageMsg::Reconfig { epoch, program: program.clone() })
                            .map_err(|_| dead())?;
                    }
                    Ok(())
                };
                // The single lane group under construction (consecutive
                // stream ids + samples); unused on the single-sample path.
                let mut pending: (Vec<usize>, Vec<&Sample>) = (Vec::new(), Vec::new());
                // Cumulative dispatched step-cost per shard — the
                // deterministic load model behind [`least_loaded`].
                let mut load = vec![0u64; n_cores];
                // Firing-rate-aware routing: a sample whose input density
                // is below the cutoff skips lane packing entirely and
                // streams as a single-sample plane sequence, where the
                // layers' quiescence fast path elides most neuron work.
                let is_sparse = |s: &Sample| {
                    sparse_cutoff.is_some_and(|cut| {
                        let slots = (s.t_steps * s.inputs).max(1) as f64;
                        (s.nnz() as f64) < cut * slots
                    })
                };
                let mut stream = 0usize;
                for op in ops {
                    // Programs applied asynchronously through a ControlPlane
                    // handle land here, at the next sample boundary (group
                    // boundary in lane mode: the partial group goes first so
                    // already-admitted samples keep the old epoch).
                    let async_programs = control.take_pending();
                    if !async_programs.is_empty() {
                        dispatch_group(
                            &mut pending,
                            &senders,
                            &mut load,
                            &assign_tx,
                            &matrix_pool,
                            lane_width,
                            inputs,
                        )?;
                        for (epoch, program) in async_programs {
                            broadcast(epoch, &program)?;
                        }
                    }
                    match op {
                        SessionOp::Submit(sample) if lane_width == 1 => {
                            // Single-sample mode keeps the static
                            // round-robin schedule — it is the conformance
                            // fallback and oracle for the adaptive path.
                            let shard = stream % n_cores;
                            let tx = &senders[shard];
                            let _ = assign_tx.send((shard, 1));
                            for t in 0..sample.t_steps {
                                // Encode straight into a recycled pool
                                // plane — no per-timestep Vec allocation.
                                let mut plane = plane_pool.take();
                                sample.step_plane_into(t, &mut plane);
                                tx.send(StageMsg::Step { stream, plane })
                                    .map_err(|_| dead())?;
                            }
                            tx.send(StageMsg::Flush { stream, stats: ActivityStats::default() })
                                .map_err(|_| dead())?;
                            control.charge_spk_in(sample.nnz() as u64);
                            stream += 1;
                        }
                        SessionOp::Submit(sample) if is_sparse(sample) => {
                            // Sparse fallback: flush the pending group
                            // first so results stay in submission order,
                            // then stream this sample alone to the
                            // least-loaded shard as planes.
                            dispatch_group(
                                &mut pending,
                                &senders,
                                &mut load,
                                &assign_tx,
                                &matrix_pool,
                                lane_width,
                                inputs,
                            )?;
                            let shard = least_loaded(&load);
                            load[shard] += sample.t_steps as u64 + 1;
                            let _ = assign_tx.send((shard, 1));
                            let tx = &senders[shard];
                            for t in 0..sample.t_steps {
                                let mut plane = plane_pool.take();
                                sample.step_plane_into(t, &mut plane);
                                tx.send(StageMsg::Step { stream, plane })
                                    .map_err(|_| dead())?;
                            }
                            tx.send(StageMsg::Flush { stream, stats: ActivityStats::default() })
                                .map_err(|_| dead())?;
                            control.charge_spk_in(sample.nnz() as u64);
                            stream += 1;
                        }
                        SessionOp::Submit(sample) => {
                            pending.0.push(stream);
                            pending.1.push(*sample);
                            control.charge_spk_in(sample.nnz() as u64);
                            stream += 1;
                            if pending.1.len() == lane_width {
                                dispatch_group(
                                    &mut pending,
                                    &senders,
                                    &mut load,
                                    &assign_tx,
                                    &matrix_pool,
                                    lane_width,
                                    inputs,
                                )?;
                            }
                        }
                        SessionOp::Reconfig(program) => {
                            dispatch_group(
                                &mut pending,
                                &senders,
                                &mut load,
                                &assign_tx,
                                &matrix_pool,
                                lane_width,
                                inputs,
                            )?;
                            let (drained, epoch, program) =
                                control.commit_in_band(program.clone());
                            for (e, p) in drained {
                                broadcast(e, &p)?;
                            }
                            broadcast(epoch, &program)?;
                        }
                    }
                }
                dispatch_group(
                    &mut pending,
                    &senders,
                    &mut load,
                    &assign_tx,
                    &matrix_pool,
                    lane_width,
                    inputs,
                )
                // `assign_tx` drops here, which is what ends the drainer's
                // record iteration once every queued result is harvested.
            });

            // Drainer (this thread): follows the feeder's assignment
            // records in dispatch order. Units (groups or singles) pack
            // consecutive stream ids and each shard's pipeline is FIFO, so
            // the next `n` in-order results are always at the head of the
            // recorded shard's output queue — popping record by record
            // restores global order regardless of how the load balancer
            // scattered units across shards. recv_timeout (rather than
            // recv) is a liveness bound, not a latency budget: it only
            // fires if a shard produces *nothing* for a very long time (a
            // wedged/dead pipeline), abandoning the batch with an error.
            let mut results = Vec::with_capacity(n_samples);
            let mut first_err: Option<anyhow::Error> = None;
            'drain: for (shard, n) in assign_rx.iter() {
                for _ in 0..n {
                    match self.shards[shard]
                        .out_rx
                        .recv_timeout(std::time::Duration::from_secs(3600))
                    {
                        Ok(r) => {
                            debug_assert_eq!(
                                r.stream_id,
                                results.len(),
                                "shard FIFO order violated"
                            );
                            self.control.charge_spk_out(r.spikes_total);
                            results.push(r);
                        }
                        Err(_) => {
                            first_err = Some(anyhow::anyhow!(
                                "serving shard {shard} produced no result {}",
                                results.len()
                            ));
                            break 'drain;
                        }
                    }
                }
            }
            if first_err.is_some() {
                // Failure path: unblock the feeder by continuously draining
                // every shard's output (discarding — order is gone) until
                // the feeder exits; its sends either succeed into chains we
                // keep empty or fail on the dead shard. The engine is then
                // poisoned: leftover in-flight results make further batches
                // unsound, and shutdown() drains them while joining.
                while !feeder.is_finished() {
                    for shard in &self.shards {
                        while shard.out_rx.try_recv().is_ok() {}
                    }
                    std::thread::sleep(std::time::Duration::from_millis(10));
                }
            }
            // The feeder is joined explicitly (never `expect`ed): a panic
            // there must become a typed error, not a process abort.
            let fed = match feeder.join() {
                Ok(r) => r,
                Err(payload) => {
                    return Err(ServingError::WorkerPanicked {
                        worker: "session feeder".to_string(),
                        message: panic_message(payload),
                    }
                    .into())
                }
            };
            if let Some(e) = first_err {
                return Err(e);
            }
            fed?;
            // Backstop: a healthy feeder emits exactly one record slot per
            // submitted sample, so a shortfall here is a scheduler bug
            // (records ran out early), not a shard failure.
            anyhow::ensure!(
                results.len() == n_samples,
                "serving session drained {} of {n_samples} results",
                results.len()
            );
            Ok(results)
        });

        self.submitted += n_samples as u64;
        match results {
            Ok(results) => {
                // Zero-alloc invariant: the pre-filled pool covers the
                // engine's maximum in-flight footprint, so steady-state
                // streaming must not have allocated a single plane.
                debug_assert_eq!(
                    self.plane_pool.misses(),
                    pool_misses_before,
                    "steady-state streaming allocated spike planes (pool underprovisioned)"
                );
                debug_assert_eq!(
                    self.matrix_pool.misses(),
                    mat_misses_before,
                    "steady-state lane streaming allocated spike matrices (pool underprovisioned)"
                );
                self.completed += results.len() as u64;
                for r in &results {
                    self.activity.add(&r.stats);
                }
                Ok(results)
            }
            Err(e) => {
                self.poisoned = true;
                // If the batch died because a shard worker panicked,
                // surface the typed panic error instead of the generic
                // drain failure, then leave the engine shut down but
                // droppable (Drop re-runs the idempotent shutdown).
                let panicked = self.harvest_worker_panic();
                self.shutdown();
                match panicked {
                    Some(err) => Err(err.into()),
                    None => Err(e),
                }
            }
        }
    }

    /// After a failed batch, reap every shard thread that has already
    /// exited and report the first panic payload found. Only finished
    /// threads are joined (a healthy upstream stage may be parked on its
    /// input channel), and a panicked thread finishes unwinding within
    /// microseconds of killing the batch — polled briefly to close that
    /// race without ever blocking on a live worker.
    fn harvest_worker_panic(&mut self) -> Option<ServingError> {
        for _ in 0..50 {
            let mut found = None;
            for (shard_idx, shard) in self.shards.iter_mut().enumerate() {
                let mut i = 0;
                while i < shard.threads.len() {
                    if shard.threads[i].is_finished() {
                        if let Err(payload) = shard.threads.remove(i).join() {
                            found.get_or_insert(ServingError::WorkerPanicked {
                                worker: format!("shard {shard_idx} worker"),
                                message: panic_message(payload),
                            });
                        }
                    } else {
                        i += 1;
                    }
                }
            }
            if found.is_some() {
                return found;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        None
    }

    /// Capture the complete engine state as a versioned
    /// [`Connectome`](super::connectome::Connectome).
    ///
    /// The snapshot fence rides the same per-shard FIFO as the data
    /// ([`StageMsg`] `Export`), so it is taken at a **sample-group
    /// boundary**: every admitted stream has fully drained, none is
    /// queued behind it, and nothing is discarded. Callers that interleave
    /// snapshots with traffic (the network pump) serialize them between
    /// [`ServingEngine::run_session`] calls, which is exactly that
    /// boundary. `submitted == completed` in the result is the in-flight
    /// ledger's quiesce-point invariant.
    pub fn snapshot(&mut self) -> Result<super::connectome::Connectome> {
        anyhow::ensure!(
            !self.poisoned,
            "serving engine poisoned by an earlier failed batch; nothing coherent to snapshot"
        );
        let num_layers = self.config.num_layers();
        let mut layers = Vec::with_capacity(self.shards.len());
        for shard in &self.shards {
            let tx = match &shard.in_tx {
                Some(tx) => tx.clone(),
                None => return Err(ServingError::ShutDown.into()),
            };
            let (reply_tx, reply_rx) = std::sync::mpsc::channel();
            tx.send(StageMsg::Export { reply: reply_tx })
                .map_err(|_| anyhow::anyhow!("serving shard died"))?;
            // Stage order is the FIFO order: layer k's export arrives k-th.
            let mut states = Vec::with_capacity(num_layers);
            for k in 0..num_layers {
                states.push(
                    reply_rx
                        .recv_timeout(std::time::Duration::from_secs(60))
                        .map_err(|_| anyhow::anyhow!("stage {k} never exported its state"))?,
                );
            }
            layers.push(states);
        }
        Ok(super::connectome::Connectome {
            qspec: self.config.qspec,
            mem: self.config.mem,
            cores: self.shards.len() as u16,
            lane_width: self.lane_width as u16,
            sizes: self.config.sizes().iter().map(|&s| s as u32).collect(),
            topologies: (0..num_layers).map(|k| self.config.layer(k).topology).collect(),
            epoch: self.control.epoch(),
            bus: self.control.bus(),
            activity: self.activity,
            submitted: self.submitted,
            completed: self.completed,
            layers,
        })
    }

    /// Revive a snapshot as a fresh, live engine — bit-exact: geometry,
    /// registers, packed weights, neuron banks (single-sample and
    /// lane-major), config epoch, and all ledgers continue exactly where
    /// [`ServingEngine::snapshot`] fenced them. The differential gate in
    /// `tests/connectome.rs` proves run-k-then-restore ≡ uninterrupted.
    ///
    /// Everything is validated *before* any stage applies anything (the
    /// decoded geometry rebuilds the [`ModelConfig`]; weight payloads are
    /// checked against the topology stores' packed sizes and the
    /// quantization range), so a bad snapshot is a typed error with no
    /// partially-restored engine left behind.
    pub fn from_connectome(c: &super::connectome::Connectome) -> Result<ServingEngine> {
        let sizes: Vec<usize> = c.sizes.iter().map(|&s| s as usize).collect();
        let config = ModelConfig::with_topologies(&sizes, &c.topologies, c.qspec)?.with_mem(c.mem);
        let mut regs = RegisterFile::new(c.qspec);
        let vector = c.register_vector()?;
        let program: Vec<(usize, i32)> = vector.iter().copied().enumerate().collect();
        regs.apply_program(&program)?;
        // Zero dense weights satisfy every topology mask; the real packed
        // payloads land through the Import fence below.
        let zeros: Vec<Vec<i32>> =
            config.layers().iter().map(|l| vec![0i32; l.fan_in * l.neurons]).collect();
        let options = ServingOptions::with_lanes(c.cores as usize, c.lane_width as usize);
        let mut engine = ServingEngine::new(&config, &zeros, &regs, options)?;
        anyhow::ensure!(
            c.layers.len() == engine.shards.len(),
            "snapshot has {} shard sections for a {}-shard engine",
            c.layers.len(),
            engine.shards.len()
        );
        let packed_sizes = engine.control.packed_sizes().to_vec();
        for states in &c.layers {
            // The decoder checked neuron-bank arity against the snapshot's
            // own geometry; weight payloads are validated here against the
            // rebuilt topology stores, reusing the control plane's wt_in
            // contract so Import cannot fail stage-side.
            let mut probe = ReconfigProgram::new();
            for (k, st) in states.iter().enumerate() {
                probe = probe.swap_weights(k, st.weights.clone());
            }
            probe.validate_weights(config.qspec, &packed_sizes)?;
        }
        for (shard, states) in engine.shards.iter().zip(&c.layers) {
            let tx = shard.in_tx.as_ref().expect("freshly built engine").clone();
            let (ack_tx, ack_rx) = std::sync::mpsc::channel();
            tx.send(StageMsg::Import { states: Arc::new(states.clone()), reply: ack_tx })
                .map_err(|_| anyhow::anyhow!("serving shard died"))?;
            for k in 0..packed_sizes.len() {
                ack_rx
                    .recv_timeout(std::time::Duration::from_secs(60))
                    .map_err(|_| anyhow::anyhow!("stage {k} never acked its import"))?;
            }
        }
        engine.control.seed(c.epoch, c.bus);
        engine.submitted = c.submitted;
        engine.completed = c.completed;
        engine.activity = c.activity;
        Ok(engine)
    }

    /// Drop the admission side and join all stage threads. Keeps draining
    /// the output channels while waiting so a collector blocked on a full
    /// channel (possible after a poisoned batch) can always make progress.
    pub fn shutdown(&mut self) {
        for shard in &mut self.shards {
            shard.in_tx = None; // closes the chain; stages drain and exit
        }
        loop {
            let mut all_done = true;
            for shard in &self.shards {
                while shard.out_rx.try_recv().is_ok() {}
                if shard.threads.iter().any(|t| !t.is_finished()) {
                    all_done = false;
                }
            }
            if all_done {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        for shard in &mut self.shards {
            for t in shard.threads.drain(..) {
                let _ = t.join();
            }
        }
    }
}

impl Drop for ServingEngine {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::registers::REG_VTH;
    use crate::datasets::{Dataset, Split};
    use crate::fixed::Q5_3;
    use crate::hdl::Core;

    fn setup() -> (ModelConfig, Vec<Vec<i32>>, RegisterFile, Vec<Sample>) {
        let cfg = ModelConfig::parse_arch("256x24x10", Q5_3).unwrap();
        let mut rng = crate::datasets::rng::XorShift64Star::new(0x5E21);
        let weights: Vec<Vec<i32>> = cfg
            .layers()
            .iter()
            .map(|l| (0..l.fan_in * l.neurons).map(|_| rng.below(15) as i32 - 7).collect())
            .collect();
        let regs = RegisterFile::new(Q5_3);
        let samples: Vec<Sample> =
            (0..9).map(|i| Dataset::Smnist.sample(i, Split::Test, 6)).collect();
        (cfg, weights, regs, samples)
    }

    #[test]
    fn engine_matches_sequential_core_bitexact() {
        let (cfg, weights, regs, samples) = setup();
        let mut core = Core::new(cfg.clone());
        core.load_weights(&weights).unwrap();
        core.registers = regs.clone();
        for cores in [1usize, 2, 3] {
            let mut engine =
                ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_cores(cores))
                    .unwrap();
            let out = engine.run_batch(&samples).unwrap();
            assert_eq!(out.len(), samples.len());
            for (i, (r, s)) in out.iter().zip(&samples).enumerate() {
                let seq = core.run(s);
                assert_eq!(r.counts, seq.counts, "cores={cores} sample {i}");
                assert_eq!(r.prediction, seq.prediction, "cores={cores} sample {i}");
                assert_eq!(r.stats, seq.stats, "cores={cores} sample {i} activity ledger");
                assert_eq!(r.stream_id, i);
                assert_eq!(r.epoch, 0);
            }
        }
    }

    #[test]
    fn engine_is_reusable_across_batches() {
        let (cfg, weights, regs, samples) = setup();
        let mut engine =
            ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_cores(2)).unwrap();
        let a = engine.run_batch(&samples).unwrap();
        let b = engine.run_batch(&samples).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.counts, y.counts, "state leaked across batches");
        }
        assert_eq!(engine.stats(), (2 * samples.len() as u64, 2 * samples.len() as u64));
    }

    #[test]
    fn small_queue_depth_still_completes() {
        let (cfg, weights, regs, samples) = setup();
        let mut engine = ServingEngine::new(
            &cfg,
            &weights,
            &regs,
            ServingOptions { cores: 2, queue_depth: 1, ..Default::default() },
        )
        .unwrap();
        let out = engine.run_batch(&samples).unwrap();
        assert_eq!(out.len(), samples.len());
    }

    #[test]
    fn empty_batch_and_bad_options() {
        let (cfg, weights, regs, _) = setup();
        assert!(ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_cores(0)).is_err());
        let mut engine =
            ServingEngine::new(&cfg, &weights, &regs, ServingOptions::default()).unwrap();
        assert!(engine.run_batch(&[]).unwrap().is_empty());
        let bad = Sample { spikes: vec![0; 4], t_steps: 1, inputs: 4, label: 0 };
        assert!(engine.run_batch(&[bad]).is_err());
    }

    #[test]
    fn sparse_topology_engine_is_bitexact_and_reports_footprint() {
        use crate::config::Topology;
        let cfg = ModelConfig::with_topologies(
            &[40, 40, 10],
            &[Topology::Gaussian { radius: 1 }, Topology::AllToAll],
            Q5_3,
        )
        .unwrap();
        let mut rng = crate::datasets::rng::XorShift64Star::new(0x5EAC);
        let weights: Vec<Vec<i32>> = cfg
            .layers()
            .iter()
            .map(|l| {
                let mask = l.topology.mask(l.fan_in, l.neurons).unwrap();
                mask.iter()
                    .map(|&a| if a == 0 { 0 } else { rng.below(15) as i32 - 7 })
                    .collect()
            })
            .collect();
        let regs = RegisterFile::new(Q5_3);
        let samples: Vec<Sample> = (0..6)
            .map(|_| {
                let t_steps = 8;
                let spikes = (0..t_steps * 40).map(|_| (rng.uniform() < 0.3) as u8).collect();
                Sample { spikes, t_steps, inputs: 40, label: 0 }
            })
            .collect();
        let mut core = Core::new(cfg.clone());
        core.load_weights(&weights).unwrap();
        let mut engine =
            ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_cores(2)).unwrap();
        // Banded first layer: 3*40 - 2 words, not the dense 1600.
        assert_eq!(engine.synapse_words_per_shard(), (3 * 40 - 2) + 40 * 10);
        assert_eq!(engine.synapse_words_per_shard(), cfg.total_synapses());
        let out = engine.run_batch(&samples).unwrap();
        for (i, (r, s)) in out.iter().zip(&samples).enumerate() {
            assert_eq!(r.counts, core.run(s).counts, "sample {i}");
        }
    }

    #[test]
    fn streaming_is_zero_alloc_after_construction() {
        // The recycled-plane pool is pre-filled at construction, so no
        // batch — first or later, even at queue_depth 1 — may allocate a
        // single spike plane on the streaming path.
        let (cfg, weights, regs, samples) = setup();
        for depth in [1usize, 4, 64] {
            let mut engine = ServingEngine::new(
                &cfg,
                &weights,
                &regs,
                ServingOptions { cores: 2, queue_depth: depth, ..Default::default() },
            )
            .unwrap();
            for _ in 0..3 {
                engine.run_batch(&samples).unwrap();
            }
            assert_eq!(
                engine.plane_pool_misses(),
                0,
                "queue_depth {depth}: streaming path allocated planes"
            );
        }
    }

    /// Ragged samples (unequal stream lengths) for the lane-batched gates.
    fn ragged_samples(count: usize) -> Vec<Sample> {
        (0..count as u64)
            .map(|i| {
                let mut s = Dataset::Smnist.sample(i, Split::Test, 3 + (i % 5) as usize);
                s.label = i as usize % 10;
                s
            })
            .collect()
    }

    #[test]
    fn lane_batched_engine_matches_single_sample_engine_bitexact() {
        // Lane widths 2 / 7 / 64 on ragged batches (count not a multiple of
        // the width, unequal stream lengths) must be bit-identical — counts,
        // prediction, stream order, epoch, and the full per-stream activity
        // ledger — to the single-sample engine and the sequential core.
        let (cfg, weights, regs, _) = setup();
        let samples = ragged_samples(13);
        let mut core = Core::new(cfg.clone());
        core.load_weights(&weights).unwrap();
        core.registers = regs.clone();
        for cores in [1usize, 2] {
            for lane_width in [2usize, 7, 64] {
                let mut engine = ServingEngine::new(
                    &cfg,
                    &weights,
                    &regs,
                    ServingOptions::with_lanes(cores, lane_width),
                )
                .unwrap();
                assert_eq!(engine.lane_width(), lane_width);
                let out = engine.run_batch(&samples).unwrap();
                assert_eq!(out.len(), samples.len());
                for (i, (r, s)) in out.iter().zip(&samples).enumerate() {
                    let seq = core.run(s);
                    let ctx = format!("cores={cores} lanes={lane_width} sample {i}");
                    assert_eq!(r.stream_id, i, "{ctx}");
                    assert_eq!(r.counts, seq.counts, "{ctx}");
                    assert_eq!(r.prediction, seq.prediction, "{ctx}");
                    assert_eq!(r.stats, seq.stats, "{ctx} activity ledger");
                    assert_eq!(r.epoch, 0, "{ctx}");
                }
            }
        }
    }

    #[test]
    fn lane_batched_engine_is_reusable_and_zero_alloc() {
        let (cfg, weights, regs, _) = setup();
        let samples = ragged_samples(10);
        for depth in [1usize, 4] {
            let mut engine = ServingEngine::new(
                &cfg,
                &weights,
                &regs,
                ServingOptions {
                    cores: 2,
                    queue_depth: depth,
                    lane_width: 4,
                    ..Default::default()
                },
            )
            .unwrap();
            let a = engine.run_batch(&samples).unwrap();
            let b = engine.run_batch(&samples).unwrap();
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.counts, y.counts, "lane state leaked across batches");
            }
            assert_eq!(
                engine.matrix_pool_misses(),
                0,
                "queue_depth {depth}: lane streaming allocated matrices"
            );
            assert_eq!(engine.plane_pool_misses(), 0, "queue_depth {depth}");
        }
    }

    #[test]
    fn least_loaded_lane_dispatch_is_bitexact_and_deterministic() {
        // Heavily skewed stream lengths create hot and idle shards; the
        // least-dispatched-work balancer must still return bit-exact,
        // in-order results — and because the schedule is a pure function
        // of the op stream (never of thread timing), two identical
        // engines must agree on every result and on their final
        // connectome images (per-shard lane-bank shapes included).
        let (cfg, weights, regs, _) = setup();
        let samples: Vec<Sample> = (0..17u64)
            .map(|i| Dataset::Smnist.sample(i, Split::Test, 1 + ((i * i * 7) % 23) as usize))
            .collect();
        let mut core = Core::new(cfg.clone());
        core.load_weights(&weights).unwrap();
        core.registers = regs.clone();
        for cores in [2usize, 3] {
            for lane_width in [3usize, 8] {
                let opts = ServingOptions::with_lanes(cores, lane_width);
                let mut engine = ServingEngine::new(&cfg, &weights, &regs, opts).unwrap();
                let mut twin = ServingEngine::new(&cfg, &weights, &regs, opts).unwrap();
                let out = engine.run_batch(&samples).unwrap();
                let out_twin = twin.run_batch(&samples).unwrap();
                assert_eq!(out.len(), samples.len());
                for (i, (r, s)) in out.iter().zip(&samples).enumerate() {
                    let seq = core.run(s);
                    let ctx = format!("cores={cores} lanes={lane_width} sample {i}");
                    assert_eq!(r.stream_id, i, "{ctx}");
                    assert_eq!(r.counts, seq.counts, "{ctx}");
                    assert_eq!(r.stats, seq.stats, "{ctx} activity ledger");
                    let t = &out_twin[i];
                    assert_eq!(r.counts, t.counts, "{ctx}: twin diverged");
                    assert_eq!(r.stats, t.stats, "{ctx}: twin ledger diverged");
                }
                assert_eq!(
                    engine.snapshot().unwrap(),
                    twin.snapshot().unwrap(),
                    "cores={cores} lanes={lane_width}: shard schedule diverged between twins"
                );
            }
        }
    }

    #[test]
    fn sparse_cutoff_fallback_is_bitexact_and_zero_alloc() {
        // A lane engine with a firing-density cutoff routes near-silent
        // samples down the single-sample quiescence path; results must be
        // bit-identical to the sequential core and to a cutoff-less lane
        // engine, in order, with both recycled-buffer pools staying warm.
        let (cfg, weights, regs, _) = setup();
        let mut rng = crate::datasets::rng::XorShift64Star::new(0x51AB);
        let samples: Vec<Sample> = (0..12u64)
            .map(|i| {
                if i % 3 == 0 {
                    // Near-silent: a handful of spikes over 9 timesteps
                    // (density « 5%), below the routing cutoff.
                    let t_steps = 9;
                    let mut spikes = vec![0u8; t_steps * 256];
                    for _ in 0..4 {
                        let slot = rng.below((t_steps * 256) as u64) as usize;
                        spikes[slot] = 1;
                    }
                    Sample { spikes, t_steps, inputs: 256, label: 0 }
                } else {
                    Dataset::Smnist.sample(i, Split::Test, 6)
                }
            })
            .collect();
        let mut core = Core::new(cfg.clone());
        core.load_weights(&weights).unwrap();
        core.registers = regs.clone();
        let mut dense =
            ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_lanes(2, 4)).unwrap();
        let mut routed = ServingEngine::new(
            &cfg,
            &weights,
            &regs,
            ServingOptions::with_lanes(2, 4).sparse_cutoff(0.05),
        )
        .unwrap();
        assert_eq!(routed.sparse_cutoff(), Some(0.05));
        let base = dense.run_batch(&samples).unwrap();
        let out = routed.run_batch(&samples).unwrap();
        assert_eq!(out.len(), samples.len());
        for (i, (r, s)) in out.iter().zip(&samples).enumerate() {
            let seq = core.run(s);
            assert_eq!(r.stream_id, i, "sample {i}");
            assert_eq!(r.counts, seq.counts, "sample {i} vs sequential core");
            assert_eq!(r.stats, seq.stats, "sample {i} activity ledger");
            assert_eq!(r.counts, base[i].counts, "sample {i} vs cutoff-less lane engine");
        }
        assert_eq!(routed.plane_pool_misses(), 0, "sparse fallback allocated planes");
        assert_eq!(routed.matrix_pool_misses(), 0, "lane path allocated matrices");
    }

    #[test]
    fn lane_batched_in_band_reconfig_splits_epochs_deterministically() {
        // A reconfiguration mid-session on a lane-batched engine must land
        // exactly between samples 3 and 4 even though 3 is mid-group (the
        // feeder flushes partial groups before broadcasting).
        let (cfg, weights, regs, _) = setup();
        let samples = ragged_samples(8);
        let mut engine =
            ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_lanes(2, 64)).unwrap();
        let mut raised = regs.clone();
        raised.set_vth(4.0).unwrap();
        let ops: Vec<SessionOp> = samples[..3]
            .iter()
            .map(SessionOp::Submit)
            .chain(std::iter::once(SessionOp::Reconfig(ReconfigProgram::from_registers(
                &raised,
            ))))
            .chain(samples[3..].iter().map(SessionOp::Submit))
            .collect();
        let out = engine.run_session(&ops).unwrap();
        assert_eq!(out.len(), 8);
        assert!(out[..3].iter().all(|r| r.epoch == 0), "pre-reconfig samples at epoch 0");
        assert!(out[3..].iter().all(|r| r.epoch == 1), "post-reconfig samples at epoch 1");
        let mut core = Core::new(cfg.clone());
        core.load_weights(&weights).unwrap();
        core.registers = regs.clone();
        for (i, s) in samples[..3].iter().enumerate() {
            assert_eq!(out[i].counts, core.run(s).counts, "epoch 0 sample {i}");
        }
        core.registers = raised;
        for (i, s) in samples[3..].iter().enumerate() {
            assert_eq!(out[3 + i].counts, core.run(s).counts, "epoch 1 sample {i}");
        }
    }

    #[test]
    fn lane_width_validated() {
        let (cfg, weights, regs, _) = setup();
        for lane_width in [0usize, 65] {
            assert!(
                ServingEngine::new(
                    &cfg,
                    &weights,
                    &regs,
                    ServingOptions { cores: 2, queue_depth: 8, lane_width, ..Default::default() },
                )
                .is_err(),
                "lane width {lane_width} must be rejected"
            );
        }
    }

    #[test]
    fn shutdown_is_idempotent() {
        let (cfg, weights, regs, samples) = setup();
        let mut engine =
            ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_cores(2)).unwrap();
        let _ = engine.run_batch(&samples[..2]).unwrap();
        engine.shutdown();
        engine.shutdown();
    }

    #[test]
    fn in_band_reconfig_splits_epochs_deterministically() {
        let (cfg, weights, regs, samples) = setup();
        let mut engine =
            ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_cores(3)).unwrap();
        let mut raised = regs.clone();
        raised.set_vth(4.0).unwrap();
        let ops: Vec<SessionOp> = samples[..3]
            .iter()
            .map(SessionOp::Submit)
            .chain(std::iter::once(SessionOp::Reconfig(ReconfigProgram::from_registers(
                &raised,
            ))))
            .chain(samples[3..6].iter().map(SessionOp::Submit))
            .collect();
        let out = engine.run_session(&ops).unwrap();
        assert_eq!(out.len(), 6);
        assert!(out[..3].iter().all(|r| r.epoch == 0), "pre-reconfig samples at epoch 0");
        assert!(out[3..].iter().all(|r| r.epoch == 1), "post-reconfig samples at epoch 1");

        // Per epoch, bit-identical to a sequential core with that config.
        let mut core = Core::new(cfg.clone());
        core.load_weights(&weights).unwrap();
        core.registers = regs.clone();
        for (i, s) in samples[..3].iter().enumerate() {
            assert_eq!(out[i].counts, core.run(s).counts, "epoch 0 sample {i}");
        }
        core.registers = raised;
        for (i, s) in samples[3..6].iter().enumerate() {
            assert_eq!(out[3 + i].counts, core.run(s).counts, "epoch 1 sample {i}");
        }
        assert_eq!(engine.epoch(), 1);
    }

    #[test]
    fn async_apply_lands_at_batch_boundary() {
        let (cfg, weights, regs, samples) = setup();
        let mut engine =
            ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_cores(2)).unwrap();
        let control = engine.control_plane();
        let a = engine.run_batch(&samples[..4]).unwrap();
        assert!(a.iter().all(|r| r.epoch == 0));
        let epoch = control
            .apply(ReconfigProgram::new().write(REG_VTH, Q5_3.from_float(4.0)))
            .unwrap();
        assert_eq!(epoch, 1);
        let b = engine.run_batch(&samples[..4]).unwrap();
        assert!(b.iter().all(|r| r.epoch == 1), "pending program must land before the batch");
        // Raising the threshold can only reduce (or keep) spiking.
        let spikes_a: u64 = a.iter().map(|r| r.stats.spikes).sum();
        let spikes_b: u64 = b.iter().map(|r| r.stats.spikes).sum();
        assert!(spikes_b <= spikes_a, "vth raise increased spiking ({spikes_a} -> {spikes_b})");
    }

    #[test]
    fn weight_swap_on_live_engine_is_bitexact() {
        let (cfg, weights, regs, samples) = setup();
        let mut engine =
            ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_cores(2)).unwrap();
        // New last-layer weights, delivered packed (all-to-all: packed ==
        // dense row-major).
        let mut rng = crate::datasets::rng::XorShift64Star::new(0xBEEF);
        let new_last: Vec<i32> =
            (0..weights[1].len()).map(|_| rng.below(15) as i32 - 7).collect();
        let ops = [
            SessionOp::Submit(&samples[0]),
            SessionOp::Reconfig(ReconfigProgram::new().swap_weights(1, new_last.clone())),
            SessionOp::Submit(&samples[1]),
        ];
        let out = engine.run_session(&ops).unwrap();
        assert_eq!((out[0].epoch, out[1].epoch), (0, 1));

        let mut core = Core::new(cfg.clone());
        core.load_weights(&weights).unwrap();
        assert_eq!(out[0].counts, core.run(&samples[0]).counts);
        core.load_weights(&[weights[0].clone(), new_last]).unwrap();
        assert_eq!(out[1].counts, core.run(&samples[1]).counts, "swapped weights diverged");
        // wt beats charged per shard on the same ledger as data traffic.
        let bus = engine.bus();
        assert_eq!(bus.wt_writes, 2 * weights[1].len() as u64);
        assert!(bus.spk_in_events > 0 && bus.beats() > bus.wt_writes);
    }

    #[test]
    fn panicked_worker_yields_typed_error_not_abort() {
        // The headline bugfix: a panicking stage thread used to take the
        // whole process down through `join().expect(...)`. Inject a panic
        // into stage 1 of every shard via a chaos program and require a
        // typed ServingError::WorkerPanicked instead — the process (and
        // every other tenant) stays alive.
        let (cfg, weights, regs, samples) = setup();
        let mut engine =
            ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_cores(2)).unwrap();
        let ops = [
            SessionOp::Submit(&samples[0]),
            SessionOp::Reconfig(ReconfigProgram::new().chaos_panic(1)),
            SessionOp::Submit(&samples[1]),
        ];
        let err = engine.run_session(&ops).unwrap_err();
        let ServingError::WorkerPanicked { worker, message } = err
            .downcast_ref::<ServingError>()
            .expect("panic must surface as the typed ServingError");
        assert!(worker.contains("shard"), "panic attributed to a shard worker: {worker}");
        assert!(message.contains("chaos"), "panic payload preserved: {message}");
        // Shut-down-but-droppable: the engine refuses further batches with
        // a poisoned-engine error, and dropping it is clean.
        let refused = engine.run_batch(&samples[..1]).unwrap_err();
        assert!(refused.to_string().contains("poisoned"), "{refused}");
        drop(engine);
    }

    #[test]
    fn panicked_pipeline_stage_yields_typed_error() {
        // Same contract for the one-shot scoped executor: a worker panic
        // must become ServingError::WorkerPanicked, never a scope-exit
        // abort. Drive the shared stage_loop directly with a chaos program.
        let chain = std::thread::scope(|scope| {
            let (tx_in, rx_in) = sync_channel::<StageMsg>(4);
            let (tx_out, rx_out) = sync_channel::<StageMsg>(4);
            let cfg = ModelConfig::parse_arch("4x3", Q5_3).unwrap();
            let layer = build_layers(&cfg, &[vec![0; 12]]).unwrap().remove(0);
            let handle = scope.spawn(move || {
                stage_loop(
                    0,
                    layer,
                    RegisterFile::new(Q5_3),
                    rx_in,
                    tx_out,
                    Vec::new(),
                    Vec::new(),
                )
            });
            let program = Arc::new(ReconfigProgram::new().chaos_panic(0));
            tx_in.send(StageMsg::Reconfig { epoch: 1, program }).unwrap();
            drop(tx_in);
            drop(rx_out);
            handle.join()
        });
        let payload = chain.expect_err("stage must have panicked");
        assert!(panic_message(payload).contains("chaos"));
    }

    #[test]
    fn invalid_in_band_program_fails_before_admission() {
        let (cfg, weights, regs, samples) = setup();
        let mut engine =
            ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_cores(2)).unwrap();
        let ops = [
            SessionOp::Submit(&samples[0]),
            SessionOp::Reconfig(ReconfigProgram::new().write(99, 0)),
        ];
        assert!(engine.run_session(&ops).is_err());
        // The engine is not poisoned: validation failed up front, nothing
        // was admitted.
        let out = engine.run_batch(&samples[..2]).unwrap();
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn submit_after_shutdown_is_typed_error_not_panic() {
        // Regression: submitting to a shut-down engine used to hit
        // `.expect("engine not shut down")` on the closed admission
        // channel and panic the caller. It must be a typed ShutDown error.
        let (cfg, weights, regs, samples) = setup();
        let mut engine =
            ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_cores(2)).unwrap();
        engine.shutdown();
        let err = engine.run_batch(&samples[..2]).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<ServingError>(), Some(ServingError::ShutDown)),
            "expected ServingError::ShutDown, got: {err:#}"
        );
        // Snapshot after shutdown takes the same typed path.
        let err = engine.snapshot().unwrap_err();
        assert!(
            matches!(err.downcast_ref::<ServingError>(), Some(ServingError::ShutDown)),
            "expected ServingError::ShutDown from snapshot, got: {err:#}"
        );
    }

    #[test]
    fn snapshot_restore_roundtrips_bitexact() {
        // Unit-level differential check (the cross-topology × lane-width
        // gate lives in tests/connectome.rs): run a prefix, snapshot,
        // revive, and require the remainder — and the final snapshot — to
        // be bit-identical to the uninterrupted engine.
        let (cfg, weights, regs, samples) = setup();
        let opts = ServingOptions::with_cores(2);
        let mut uninterrupted = ServingEngine::new(&cfg, &weights, &regs, opts).unwrap();
        let mut donor = ServingEngine::new(&cfg, &weights, &regs, opts).unwrap();
        let _ = uninterrupted.run_batch(&samples[..4]).unwrap();
        let _ = donor.run_batch(&samples[..4]).unwrap();
        let snap = donor.snapshot().unwrap();
        assert_eq!((snap.submitted, snap.completed), (4, 4), "quiesce-point invariant");
        let bytes = snap.encode();
        let decoded = super::super::connectome::Connectome::decode(&bytes).unwrap();
        assert_eq!(decoded, snap, "wire roundtrip must be identity");
        let mut revived = ServingEngine::from_connectome(&decoded).unwrap();
        let a = uninterrupted.run_batch(&samples[4..]).unwrap();
        let b = revived.run_batch(&samples[4..]).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.counts, y.counts, "restored engine diverged");
            assert_eq!(x.stats, y.stats, "restored activity ledger diverged");
            assert_eq!(x.epoch, y.epoch);
        }
        // Whole-state equivalence: the two engines snapshot identically.
        assert_eq!(revived.snapshot().unwrap(), uninterrupted.snapshot().unwrap());
    }

    #[test]
    fn migrate_applies_snapshot_as_one_epoch() {
        let (cfg, weights, regs, samples) = setup();
        // Donor carries different weights and a raised threshold.
        let mut rng = crate::datasets::rng::XorShift64Star::new(0xD02);
        let donor_weights: Vec<Vec<i32>> = cfg
            .layers()
            .iter()
            .map(|l| (0..l.fan_in * l.neurons).map(|_| rng.below(15) as i32 - 7).collect())
            .collect();
        let mut donor_regs = regs.clone();
        donor_regs.set_vth(4.0).unwrap();
        let mut donor = ServingEngine::new(
            &cfg,
            &donor_weights,
            &donor_regs,
            ServingOptions::with_cores(1),
        )
        .unwrap();
        let snap = donor.snapshot().unwrap();

        let mut live =
            ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_cores(2)).unwrap();
        let _ = live.run_batch(&samples[..2]).unwrap();
        let control = live.control_plane();
        let before = control.epoch();
        let epoch = control.migrate(&snap).unwrap();
        assert_eq!(epoch, before + 1, "migration must be exactly one config epoch");
        // Post-migration results are bit-identical to a sequential core
        // built with the donor's weights and registers.
        let out = live.run_batch(&samples[..3]).unwrap();
        let mut core = Core::new(cfg.clone());
        core.load_weights(&donor_weights).unwrap();
        core.registers = donor_regs;
        for (r, s) in out.iter().zip(&samples[..3]) {
            assert_eq!(r.counts, core.run(s).counts, "migrated engine diverged from donor");
            assert_eq!(r.epoch, epoch);
        }
        // Geometry mismatch is rejected with a typed error, atomically.
        let narrow = ModelConfig::parse_arch("4x3", Q5_3).unwrap();
        let narrow_engine = ServingEngine::new(
            &narrow,
            &[vec![0; 12]],
            &RegisterFile::new(Q5_3),
            ServingOptions::with_cores(1),
        )
        .unwrap();
        let err = narrow_engine.control_plane().migrate(&snap).unwrap_err();
        assert!(
            matches!(
                err,
                super::super::control::ControlError::SnapshotMismatch { .. }
                    | super::super::control::ControlError::PayloadSize { .. }
            ),
            "mismatched migrate must be typed: {err}"
        );
        assert_eq!(narrow_engine.control_plane().epoch(), 0, "nothing applied");
    }
}
