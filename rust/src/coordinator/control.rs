//! Live control plane — run-time cfg_in/wt_in reprogramming of a serving
//! engine (paper §II, §III-A, §VI-I Table X).
//!
//! The paper's headline claim is that QUANTISENC is *software-defined*: the
//! LIF dynamics are reprogrammed at run time through the decoder's control
//! registers (cfg_in) and the synaptic memories through wt_in, on an
//! already-deployed core. [`ControlPlane`] is that claim on the production
//! request path: it applies a [`ReconfigProgram`] (a batch of register
//! writes plus per-layer packed weight swaps) to a live
//! [`ServingEngine`](super::serving::ServingEngine) **without draining
//! traffic**.
//!
//! ## Epoch semantics
//!
//! Every accepted program is assigned a monotonically increasing **config
//! epoch** (the engine is built at epoch 0). Reconfiguration rides the
//! engine's existing bounded stage channels — the same FIFOs that carry
//! the recycled bit-packed spike planes of the data path — as epoch-tagged
//! control messages, broadcast to every shard at a *sample boundary* of
//! the admission feed. Because each shard's stage chain is FIFO, every
//! in-flight sample is processed entirely under one epoch, and each
//! [`StreamResult`](super::serving::StreamResult) carries the epoch it was
//! computed under. Per epoch, results are bit-identical to a freshly built
//! engine with that configuration — proven by
//! `rust/tests/control_plane.rs`.
//!
//! ## Validation
//!
//! [`ControlPlane::apply`] validates the whole program against the engine's
//! geometry (register address space and value domains, per-layer packed
//! payload sizes, Qn.q weight ranges) *before* assigning an epoch, and
//! rejects with a typed [`ControlError`] without mutating anything. Stages
//! therefore apply accepted programs infallibly — a half-applied
//! reconfiguration cannot exist.
//!
//! ## Bus accounting
//!
//! Accepted programs are charged to the engine's AXI ledger
//! ([`BusStats`]): each register write is one cfg beat and each packed
//! weight word one wt beat, **per shard** (the broadcast physically
//! programs every core), on the same ledger that meters spk_in/spk_out
//! data traffic. Beats are charged at *admission* (when the epoch is
//! assigned) — a program admitted right before engine shutdown is already
//! on the ledger, mirroring a posted AXI write that was issued even if
//! the device is torn down before acting on it.
//!
//! ```
//! use quantisenc::config::registers::RegisterFile;
//! use quantisenc::config::ModelConfig;
//! use quantisenc::coordinator::control::ReconfigProgram;
//! use quantisenc::coordinator::serving::{ServingEngine, ServingOptions};
//! use quantisenc::datasets::Sample;
//! use quantisenc::fixed::Q5_3;
//!
//! let cfg = ModelConfig::parse_arch("4x3x2", Q5_3)?;
//! let weights = vec![vec![4; 12], vec![4; 6]];
//! let regs = RegisterFile::new(Q5_3);
//! let mut engine = ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_cores(2))?;
//! let control = engine.control_plane();
//!
//! // Reprogram the threshold on the live engine: one cfg_in program.
//! let mut vth_regs = regs.clone();
//! vth_regs.set_vth(2.0)?;
//! let epoch = control.apply(ReconfigProgram::from_registers(&vth_regs))?;
//! assert_eq!(epoch, 1);
//!
//! // The next admitted sample is served under epoch 1.
//! let sample = Sample { spikes: vec![1; 8], t_steps: 2, inputs: 4, label: 0 };
//! let results = engine.run_batch(&[sample])?;
//! assert_eq!(results[0].epoch, 1);
//! # Ok::<(), anyhow::Error>(())
//! ```

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use crate::config::registers::{RegisterError, RegisterFile, NUM_REGS};
use crate::fixed::QSpec;

use super::interface::BusStats;

/// A batch of cfg_in register writes plus wt_in packed weight swaps — the
/// unit of run-time reconfiguration.
///
/// Programs are *declarative*: they carry raw register values (the cfg_in
/// bus encoding) and per-layer packed weight payloads (exactly the
/// physical words the layer's topology-aware store holds, see
/// [`crate::hdl::SynapticMemory::load_packed`]). Build one with the
/// builder methods, or snapshot a whole [`RegisterFile`] with
/// [`ReconfigProgram::from_registers`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ReconfigProgram {
    /// cfg_in register writes, applied in order: `(address, raw value)`.
    pub cfg: Vec<(usize, i32)>,
    /// wt_in bulk swaps: `(layer index, packed payload)` in stored order.
    pub weights: Vec<(usize, Vec<i32>)>,
    /// Fault-injection hook: make the named pipeline stage panic when this
    /// program lands, instead of applying it. Never set on real programs —
    /// it exists so tests can prove a worker panic surfaces as
    /// [`ServingError::WorkerPanicked`](super::serving::ServingError) and
    /// not a process abort. Not carried on the wire.
    pub chaos_panic_stage: Option<usize>,
}

impl ReconfigProgram {
    pub fn new() -> ReconfigProgram {
        ReconfigProgram::default()
    }

    /// Append one cfg_in register write (builder style).
    pub fn write(mut self, addr: usize, value: i32) -> ReconfigProgram {
        self.cfg.push((addr, value));
        self
    }

    /// Append one wt_in packed weight swap for `layer` (builder style).
    pub fn swap_weights(mut self, layer: usize, packed: Vec<i32>) -> ReconfigProgram {
        self.weights.push((layer, packed));
        self
    }

    /// Snapshot a full register file as an absolute 6-write cfg_in program
    /// — the idiom for "set the core to exactly this operating point"
    /// (each Table X row is one such program).
    pub fn from_registers(regs: &RegisterFile) -> ReconfigProgram {
        let v = regs.vector();
        ReconfigProgram {
            cfg: (0..NUM_REGS).map(|a| (a, v[a])).collect(),
            weights: Vec::new(),
            chaos_panic_stage: None,
        }
    }

    /// Arm the fault-injection hook: stage `stage` panics when this
    /// program lands (see [`ReconfigProgram::chaos_panic_stage`]).
    pub fn chaos_panic(mut self, stage: usize) -> ReconfigProgram {
        self.chaos_panic_stage = Some(stage);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.cfg.is_empty() && self.weights.is_empty()
    }

    /// cfg_in bus beats this program costs per programmed core.
    pub fn cfg_beats(&self) -> u64 {
        self.cfg.len() as u64
    }

    /// wt_in bus beats this program costs per programmed core.
    pub fn wt_beats(&self) -> u64 {
        self.weights.iter().map(|(_, w)| w.len() as u64).sum()
    }

    /// Validate this program's wt_in payloads against a target geometry:
    /// `packed_sizes[k]` is layer k's physical word count and `qspec` the
    /// word format. Shared by the engine's control plane and the
    /// single-core [`Device`](super::interface::Device) so the two paths
    /// cannot drift.
    pub fn validate_weights(
        &self,
        qspec: QSpec,
        packed_sizes: &[usize],
    ) -> Result<(), ControlError> {
        for (layer, payload) in &self.weights {
            let layers = packed_sizes.len();
            if *layer >= layers {
                return Err(ControlError::BadLayer { layer: *layer, layers });
            }
            let expect = packed_sizes[*layer];
            if payload.len() != expect {
                return Err(ControlError::PayloadSize {
                    layer: *layer,
                    expect,
                    got: payload.len(),
                });
            }
            for (index, &value) in payload.iter().enumerate() {
                if !qspec.in_range(value) {
                    return Err(ControlError::WeightOutOfRange {
                        layer: *layer,
                        index,
                        value,
                        q: qspec.name(),
                    });
                }
            }
        }
        Ok(())
    }
}

/// Typed rejection of a malformed [`ReconfigProgram`] — nothing is applied
/// and no epoch is assigned.
#[derive(Debug, PartialEq)]
pub enum ControlError {
    /// A cfg_in write was rejected by the register file (bad address, bad
    /// reset-mode encoding, negative refractory, value outside Qn.q).
    Register(RegisterError),
    /// A wt_in swap addressed a layer the engine does not have.
    BadLayer { layer: usize, layers: usize },
    /// A wt_in payload does not match the layer's physical word count.
    PayloadSize { layer: usize, expect: usize, got: usize },
    /// A wt_in payload word does not fit the engine's Qn.q format.
    WeightOutOfRange { layer: usize, index: usize, value: i32, q: String },
    /// A connectome offered to [`ControlPlane::migrate`] does not describe
    /// this engine (wrong quantization, layer arity, or internally
    /// inconsistent register sections). Nothing was applied.
    SnapshotMismatch { what: &'static str },
}

impl std::fmt::Display for ControlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ControlError::Register(e) => write!(f, "cfg_in program rejected: {e}"),
            ControlError::BadLayer { layer, layers } => {
                write!(f, "wt_in swap addresses layer {layer}, engine has {layers} layers")
            }
            ControlError::PayloadSize { layer, expect, got } => write!(
                f,
                "wt_in payload for layer {layer} has {got} words, its store holds {expect}"
            ),
            ControlError::WeightOutOfRange { layer, index, value, q } => write!(
                f,
                "wt_in payload for layer {layer} word {index} = {value} does not fit {q}"
            ),
            ControlError::SnapshotMismatch { what } => {
                write!(f, "connectome does not match this engine: {what}")
            }
        }
    }
}

impl std::error::Error for ControlError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ControlError::Register(e) => Some(e),
            _ => None,
        }
    }
}

impl From<RegisterError> for ControlError {
    fn from(e: RegisterError) -> ControlError {
        ControlError::Register(e)
    }
}

/// Engine-side shared state behind every [`ControlPlane`] handle: the
/// pending program queue, the epoch counter, the shadow register file, and
/// the AXI ledger. Owned by the engine via `Arc`.
pub(crate) struct ControlShared {
    /// Validated programs awaiting broadcast at the next sample boundary,
    /// in epoch order.
    pending: Mutex<Vec<(u64, Arc<ReconfigProgram>)>>,
    /// Every committed program since the last checkpoint, in epoch order —
    /// the replay tail a supervised rebuild programs onto a revived shard.
    /// Pruned by [`ControlShared::prune_history`] once a newer checkpoint
    /// makes the prefix unreachable.
    history: Mutex<Vec<(u64, Arc<ReconfigProgram>)>>,
    /// Next epoch to assign; the engine's construction config is epoch 0.
    next_epoch: AtomicU64,
    /// Shadow register file tracking every accepted cfg_in program — what
    /// the engine's decoder registers will read once the program lands.
    regs: Mutex<RegisterFile>,
    /// The engine-wide AXI transaction ledger (§IV bus model): control
    /// beats (cfg/wt × shards) and data beats (spk_in/spk_out) together.
    bus: Mutex<BusStats>,
    /// Validation geometry, captured at engine construction.
    qspec: QSpec,
    packed_sizes: Vec<usize>,
    cores: usize,
}

/// Lock a control-plane mutex, recovering from poisoning. Every structure
/// behind these locks is a plain ledger (a Vec, a register file, a beat
/// counter) whose every mutation is complete before the guard drops, so a
/// panic elsewhere while holding the lock cannot leave it half-written —
/// the poisoned state is always valid. Without this, one panicking worker
/// would permanently take down telemetry and reconfig for every other
/// tenant's handle (the mutex-poison cascade).
fn relock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

impl ControlShared {
    pub(crate) fn new(regs: RegisterFile, packed_sizes: Vec<usize>, cores: usize) -> ControlShared {
        ControlShared {
            pending: Mutex::new(Vec::new()),
            history: Mutex::new(Vec::new()),
            next_epoch: AtomicU64::new(1),
            qspec: regs.qspec(),
            regs: Mutex::new(regs),
            bus: Mutex::new(BusStats::default()),
            packed_sizes,
            cores,
        }
    }

    /// Validate a program against the engine geometry without mutating
    /// anything. Register writes are staged on a clone of the shadow file
    /// (all-or-nothing), payloads are checked for layer address, size, and
    /// Qn.q range.
    pub(crate) fn validate(&self, program: &ReconfigProgram) -> Result<(), ControlError> {
        program.validate_weights(self.qspec, &self.packed_sizes)?;
        relock(&self.regs).clone().apply_program(&program.cfg)?;
        Ok(())
    }

    /// Queue a validated program for broadcast at the next sample boundary.
    /// Assigns the epoch, commits the shadow registers, and charges the
    /// bus ledger. Used by [`ControlPlane::apply`].
    pub(crate) fn admit(&self, program: ReconfigProgram) -> Result<u64, ControlError> {
        self.validate(&program)?;
        let program = Arc::new(program);
        let mut pending = relock(&self.pending);
        let epoch = self.commit(&program);
        pending.push((epoch, program));
        Ok(epoch)
    }

    /// Assign an epoch to an already-validated program and account for it
    /// (shadow registers + bus beats + replay history). The caller
    /// delivers the program.
    pub(crate) fn commit(&self, program: &Arc<ReconfigProgram>) -> u64 {
        relock(&self.regs)
            .apply_program(&program.cfg)
            .expect("program validated before commit");
        {
            let mut bus = relock(&self.bus);
            bus.cfg_writes += program.cfg_beats() * self.cores as u64;
            bus.wt_writes += program.wt_beats() * self.cores as u64;
        }
        let epoch = self.next_epoch.fetch_add(1, Ordering::SeqCst);
        relock(&self.history).push((epoch, Arc::clone(program)));
        epoch
    }

    /// Epoch-assign an in-band program while draining any async-pending
    /// ones ahead of it, preserving epoch delivery order.
    pub(crate) fn commit_in_band(
        &self,
        program: ReconfigProgram,
    ) -> (Vec<(u64, Arc<ReconfigProgram>)>, u64, Arc<ReconfigProgram>) {
        let program = Arc::new(program);
        let mut pending = relock(&self.pending);
        let drained = std::mem::take(&mut *pending);
        let epoch = self.commit(&program);
        (drained, epoch, program)
    }

    /// Committed programs with epoch strictly greater than `epoch`, in
    /// epoch order — the replay tail for a shard rebuilt from a
    /// checkpoint fenced at that epoch. Replay is idempotent (cfg writes
    /// are absolute, wt swaps are whole payloads), so replaying from any
    /// conservative lower bound of the checkpoint's true epoch is exact.
    pub(crate) fn programs_since(&self, epoch: u64) -> Vec<(u64, Arc<ReconfigProgram>)> {
        relock(&self.history).iter().filter(|(e, _)| *e > epoch).cloned().collect()
    }

    /// Drop history entries at or below `upto`. Safe once a checkpoint
    /// fenced at `upto` exists — no rebuild ever replays past it — which
    /// bounds history growth to one checkpoint interval of programs.
    pub(crate) fn prune_history(&self, upto: u64) {
        relock(&self.history).retain(|(e, _)| *e > upto);
    }

    /// Drain programs queued by [`ControlPlane::apply`], in epoch order.
    pub(crate) fn take_pending(&self) -> Vec<(u64, Arc<ReconfigProgram>)> {
        std::mem::take(&mut *relock(&self.pending))
    }

    pub(crate) fn epoch(&self) -> u64 {
        self.next_epoch.load(Ordering::SeqCst) - 1
    }

    pub(crate) fn registers(&self) -> RegisterFile {
        relock(&self.regs).clone()
    }

    pub(crate) fn bus(&self) -> BusStats {
        *relock(&self.bus)
    }

    pub(crate) fn charge_spk_in(&self, events: u64) {
        relock(&self.bus).spk_in_events += events;
    }

    pub(crate) fn charge_spk_out(&self, events: u64) {
        relock(&self.bus).spk_out_events += events;
    }

    /// The wt_in payload-size contract: layer k's physical word count.
    pub(crate) fn packed_sizes(&self) -> &[usize] {
        &self.packed_sizes
    }

    /// Connectome-restore seeding: continue the epoch counter and the AXI
    /// ledger exactly where the snapshot fenced them. Only called on a
    /// freshly built engine before it serves anything (the shadow register
    /// file was already seeded through the constructor).
    pub(crate) fn seed(&self, epoch: u64, bus: BusStats) {
        self.next_epoch.store(epoch + 1, Ordering::SeqCst);
        *relock(&self.bus) = bus;
    }
}

/// A cloneable, thread-safe handle for reprogramming a live
/// [`ServingEngine`](super::serving::ServingEngine).
///
/// Obtained from
/// [`ServingEngine::control_plane`](super::serving::ServingEngine::control_plane);
/// may be moved to another thread and used **while the engine is serving**
/// — accepted programs land at the next sample boundary of the admission
/// feed, so no in-flight sample ever observes a half-applied config.
///
/// ```
/// use quantisenc::config::registers::{RegisterFile, REG_VTH};
/// use quantisenc::config::ModelConfig;
/// use quantisenc::coordinator::control::{ControlError, ReconfigProgram};
/// use quantisenc::coordinator::serving::{ServingEngine, ServingOptions};
/// use quantisenc::fixed::Q5_3;
///
/// let cfg = ModelConfig::parse_arch("4x3x2", Q5_3)?;
/// let weights = vec![vec![4; 12], vec![4; 6]];
/// let regs = RegisterFile::new(Q5_3);
/// let mut engine = ServingEngine::new(&cfg, &weights, &regs, ServingOptions::default())?;
/// let control = engine.control_plane();
/// assert_eq!(control.epoch(), 0);
///
/// // Malformed programs are rejected with a typed error, epoch unchanged.
/// let err = control.apply(ReconfigProgram::new().write(99, 0)).unwrap_err();
/// assert!(matches!(err, ControlError::Register(_)));
/// assert_eq!(control.epoch(), 0);
///
/// // A valid program bumps the epoch and is charged to the AXI ledger.
/// control.apply(ReconfigProgram::new().write(REG_VTH, 16))?;
/// assert_eq!(control.epoch(), 1);
/// assert!(control.bus().cfg_writes > 0);
/// # Ok::<(), anyhow::Error>(())
/// ```
#[derive(Clone)]
pub struct ControlPlane {
    shared: Arc<ControlShared>,
}

impl ControlPlane {
    pub(crate) fn from_shared(shared: Arc<ControlShared>) -> ControlPlane {
        ControlPlane { shared }
    }

    /// Validate and admit a reconfiguration program. Returns the config
    /// epoch the program was assigned; every sample admitted after the
    /// program lands carries this epoch in its
    /// [`StreamResult::epoch`](super::serving::StreamResult::epoch).
    ///
    /// The program is broadcast to every shard at the next sample boundary
    /// of the engine's admission feed (immediately at the start of the
    /// next batch if the engine is idle). Rejection is all-or-nothing: a
    /// [`ControlError`] means no register, weight, epoch, or bus state
    /// changed.
    pub fn apply(&self, program: ReconfigProgram) -> Result<u64, ControlError> {
        self.shared.admit(program)
    }

    /// Validate a program against the engine geometry without admitting it
    /// — no epoch, register, or bus state changes. The network front door
    /// uses this to reject one tenant's malformed `Reconfig` frame with a
    /// typed per-request error before it reaches the shared engine.
    pub fn validate(&self, program: &ReconfigProgram) -> Result<(), ControlError> {
        self.shared.validate(program)
    }

    /// The latest assigned config epoch (0 until the first successful
    /// [`apply`](ControlPlane::apply)).
    pub fn epoch(&self) -> u64 {
        self.shared.epoch()
    }

    /// Shadow copy of the decoder registers after every accepted program —
    /// what the engine's cores read once all admitted programs land.
    pub fn registers(&self) -> RegisterFile {
        self.shared.registers()
    }

    /// The engine-wide AXI ledger: cfg/wt control beats (charged per
    /// shard) plus spk_in/spk_out data beats, on one meter.
    pub fn bus(&self) -> BusStats {
        self.shared.bus()
    }

    /// Blue/green migration: warm-swap a connectome's registers **and**
    /// every layer's packed weights into this live engine as **exactly one
    /// config epoch** — one atomic cfg_in + wt_in program through the
    /// ordinary [`ControlPlane::apply`] path, so it lands at the next
    /// sample boundary with no drain, no rebuild, and no stream lost.
    /// The snapshot's dynamic state (neuron banks, ledgers, epoch counter)
    /// is deliberately *not* applied — a live engine keeps its own; use
    /// [`ServingEngine::from_connectome`](super::serving::ServingEngine::from_connectome)
    /// for a full bit-exact restore.
    ///
    /// Returns the assigned epoch. A snapshot that does not describe this
    /// engine's geometry is rejected with a typed [`ControlError`] and
    /// nothing is applied.
    pub fn migrate(&self, c: &super::connectome::Connectome) -> Result<u64, ControlError> {
        if c.qspec != self.shared.qspec {
            return Err(ControlError::SnapshotMismatch { what: "quantization format differs" });
        }
        let donor = c
            .layers
            .first()
            .ok_or(ControlError::SnapshotMismatch { what: "snapshot has no layer sections" })?;
        if donor.len() != self.shared.packed_sizes.len() {
            return Err(ControlError::SnapshotMismatch { what: "layer count differs" });
        }
        let vector = c
            .register_vector()
            .map_err(|_| ControlError::SnapshotMismatch { what: "register sections disagree" })?;
        // Shards of the donor engine are identical by construction; shard
        // 0's packed stores are the canonical weight payloads. Payload
        // sizes and Qn.q range are validated by `apply` against *this*
        // engine's topology stores — a geometry mismatch that survives
        // the checks above is still rejected there, atomically.
        let mut program = ReconfigProgram::new();
        for (addr, &value) in vector.iter().enumerate() {
            program = program.write(addr, value);
        }
        for (k, st) in donor.iter().enumerate() {
            program = program.swap_weights(k, st.weights.clone());
        }
        self.apply(program)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::registers::{REG_RESET_MODE, REG_VTH};
    use crate::fixed::Q5_3;

    fn shared() -> ControlShared {
        ControlShared::new(RegisterFile::new(Q5_3), vec![12, 6], 2)
    }

    #[test]
    fn program_builder_and_beats() {
        let p = ReconfigProgram::new().write(REG_VTH, 4).swap_weights(1, vec![0; 6]);
        assert_eq!(p.cfg_beats(), 1);
        assert_eq!(p.wt_beats(), 6);
        assert!(!p.is_empty());
        assert!(ReconfigProgram::new().is_empty());
        let full = ReconfigProgram::from_registers(&RegisterFile::new(Q5_3));
        assert_eq!(full.cfg_beats(), NUM_REGS as u64);
    }

    #[test]
    fn admit_assigns_epochs_and_charges_bus() {
        let s = shared();
        assert_eq!(s.epoch(), 0);
        let e1 = s.admit(ReconfigProgram::new().write(REG_VTH, 4)).unwrap();
        let e2 = s.admit(ReconfigProgram::new().swap_weights(0, vec![1; 12])).unwrap();
        assert_eq!((e1, e2), (1, 2));
        // Per-shard charging: 1 cfg write × 2 shards, 12 wt words × 2 shards.
        assert_eq!(s.bus().cfg_writes, 2);
        assert_eq!(s.bus().wt_writes, 24);
        assert_eq!(s.take_pending().len(), 2);
        assert!(s.take_pending().is_empty());
        // Shadow registers track the accepted writes.
        assert_eq!(s.registers().vth(), 4);
    }

    #[test]
    fn rejection_is_total() {
        let s = shared();
        // One good write followed by a bad one: nothing may stick.
        let p = ReconfigProgram::new().write(REG_VTH, 4).write(REG_RESET_MODE, 9);
        assert!(matches!(s.admit(p), Err(ControlError::Register(_))));
        assert!(matches!(
            s.admit(ReconfigProgram::new().swap_weights(7, vec![])),
            Err(ControlError::BadLayer { layer: 7, layers: 2 })
        ));
        assert_eq!(
            s.admit(ReconfigProgram::new().swap_weights(0, vec![0; 3])),
            Err(ControlError::PayloadSize { layer: 0, expect: 12, got: 3 })
        );
        assert!(matches!(
            s.admit(ReconfigProgram::new().swap_weights(1, vec![9000; 6])),
            Err(ControlError::WeightOutOfRange { layer: 1, index: 0, .. })
        ));
        assert_eq!(s.epoch(), 0);
        assert_eq!(s.bus(), BusStats::default());
        assert_eq!(s.registers().vth(), RegisterFile::new(Q5_3).vth());
        assert!(s.take_pending().is_empty());
    }

    #[test]
    fn in_band_commit_preserves_epoch_order() {
        let s = shared();
        s.admit(ReconfigProgram::new().write(REG_VTH, 4)).unwrap();
        let (drained, epoch, _) = s.commit_in_band(ReconfigProgram::new().write(REG_VTH, 5));
        assert_eq!(drained.len(), 1);
        assert_eq!(drained[0].0, 1);
        assert_eq!(epoch, 2);
        assert!(s.take_pending().is_empty());
    }

    #[test]
    fn poisoned_locks_recover() {
        // A worker that panics while holding a control-plane lock must not
        // take down telemetry/reconfig for every other handle. Poison all
        // three mutexes deliberately, then prove the full API still works.
        let s = Arc::new(shared());
        for which in 0..3 {
            let s2 = Arc::clone(&s);
            // Hold exactly one lock per thread: a panicked unwrap on an
            // already-poisoned sibling would skip the one we target.
            let _ = std::thread::spawn(move || match which {
                0 => {
                    let _g = s2.bus.lock().unwrap();
                    panic!("deliberate poison");
                }
                1 => {
                    let _g = s2.regs.lock().unwrap();
                    panic!("deliberate poison");
                }
                _ => {
                    let _g = s2.pending.lock().unwrap();
                    panic!("deliberate poison");
                }
            })
            .join();
        }
        assert!(s.bus.is_poisoned() && s.regs.is_poisoned() && s.pending.is_poisoned());
        // Every accessor recovers: admit, ledger charging, reads, drains.
        let epoch = s.admit(ReconfigProgram::new().write(REG_VTH, 4)).unwrap();
        assert_eq!(epoch, 1);
        s.charge_spk_in(3);
        s.charge_spk_out(2);
        assert_eq!(s.bus().cfg_writes, 2); // 1 write × 2 shards
        assert_eq!(s.bus().spk_in_events, 3);
        assert_eq!(s.registers().vth(), 4);
        assert_eq!(s.take_pending().len(), 1);
        // Rejection still validates against the recovered shadow file.
        assert!(s.admit(ReconfigProgram::new().write(99, 0)).is_err());
    }

    #[test]
    fn history_tracks_commits_and_prunes() {
        let s = shared();
        s.admit(ReconfigProgram::new().write(REG_VTH, 4)).unwrap(); // epoch 1
        let (_, e2, _) = s.commit_in_band(ReconfigProgram::new().write(REG_VTH, 5)); // epoch 2
        assert_eq!(e2, 2);
        let epochs: Vec<u64> = s.programs_since(0).iter().map(|(e, _)| *e).collect();
        assert_eq!(epochs, vec![1, 2]);
        assert_eq!(s.programs_since(1).len(), 1);
        assert!(s.programs_since(2).is_empty());
        // Pruning below a checkpoint keeps the replay tail reachable.
        s.prune_history(1);
        let epochs: Vec<u64> = s.programs_since(0).iter().map(|(e, _)| *e).collect();
        assert_eq!(epochs, vec![2]);
        s.prune_history(2);
        assert!(s.programs_since(0).is_empty());
        // Rejected programs never enter history.
        assert!(s.admit(ReconfigProgram::new().write(99, 0)).is_err());
        assert!(s.programs_since(0).is_empty());
    }

    #[test]
    fn chaos_program_builder() {
        let p = ReconfigProgram::new().write(REG_VTH, 4).chaos_panic(1);
        assert_eq!(p.chaos_panic_stage, Some(1));
        assert!(ReconfigProgram::from_registers(&RegisterFile::new(Q5_3))
            .chaos_panic_stage
            .is_none());
    }

    #[test]
    fn control_error_display_is_actionable() {
        let e = ControlError::PayloadSize { layer: 1, expect: 6, got: 3 };
        assert!(e.to_string().contains("layer 1"));
        let e: ControlError = RegisterError::BadAddress(99).into();
        assert!(e.to_string().contains("cfg_in program rejected"));
    }
}
