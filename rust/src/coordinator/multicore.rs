//! Batch-level parallelism — paper §IV footnote 1: "multiple batches of
//! input data are processed concurrently on different processing elements".
//!
//! A [`MultiCore`] owns C identical programmed cores and shards a batch of
//! samples across them with worker threads. Results are returned in input
//! order and must be identical to a single core processing the batch
//! sequentially (determinism is asserted in tests). Each worker runs the
//! event-driven packed datapath ([`crate::hdl::Core::run`] encodes every
//! timestep into a recycled bit-packed [`crate::hdl::SpikePlane`]), so the
//! per-core hot loop does O(popcount) ActGen work per step.

use anyhow::Result;

use crate::config::registers::RegisterFile;
use crate::config::ModelConfig;
use crate::datasets::Sample;
use crate::hdl::core::RunResult;
use crate::hdl::Core;

pub struct MultiCore {
    cores: Vec<Core>,
}

impl MultiCore {
    /// Build C cores with identical weights and registers.
    pub fn new(
        config: &ModelConfig,
        weights: &[Vec<i32>],
        regs: &RegisterFile,
        num_cores: usize,
    ) -> Result<MultiCore> {
        anyhow::ensure!(num_cores >= 1, "need at least one core");
        let mut cores = Vec::with_capacity(num_cores);
        for _ in 0..num_cores {
            let mut c = Core::new(config.clone());
            c.load_weights(weights)?;
            c.registers = regs.clone();
            cores.push(c);
        }
        Ok(MultiCore { cores })
    }

    pub fn num_cores(&self) -> usize {
        self.cores.len()
    }

    /// Run a batch, sharded round-robin across cores (threaded).
    pub fn run_batch(&mut self, samples: &[Sample]) -> Vec<RunResult> {
        let n_cores = self.cores.len();
        let mut slots: Vec<Option<RunResult>> = vec![None; samples.len()];
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for (core_id, core) in self.cores.iter_mut().enumerate() {
                let my_samples: Vec<(usize, &Sample)> = samples
                    .iter()
                    .enumerate()
                    .skip(core_id)
                    .step_by(n_cores)
                    .collect();
                handles.push(scope.spawn(move || {
                    my_samples
                        .into_iter()
                        .map(|(i, s)| (i, core.run(s)))
                        .collect::<Vec<_>>()
                }));
            }
            for h in handles {
                for (i, r) in h.join().expect("core worker panicked") {
                    slots[i] = Some(r);
                }
            }
        });
        slots.into_iter().map(|r| r.expect("all samples processed")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{Dataset, Split};
    use crate::fixed::Q5_3;

    fn setup() -> (ModelConfig, Vec<Vec<i32>>, RegisterFile, Vec<Sample>) {
        let cfg = ModelConfig::parse_arch("256x16x10", Q5_3).unwrap();
        let mut rng = crate::datasets::rng::XorShift64Star::new(0xACE);
        let weights: Vec<Vec<i32>> = cfg
            .layers()
            .iter()
            .map(|l| (0..l.fan_in * l.neurons).map(|_| rng.below(13) as i32 - 6).collect())
            .collect();
        let regs = RegisterFile::new(Q5_3);
        let samples: Vec<Sample> =
            (0..7).map(|i| Dataset::Smnist.sample(i, Split::Test, 8)).collect();
        (cfg, weights, regs, samples)
    }

    #[test]
    fn multicore_matches_single_core() {
        let (cfg, weights, regs, samples) = setup();
        let mut mc1 = MultiCore::new(&cfg, &weights, &regs, 1).unwrap();
        let mut mc3 = MultiCore::new(&cfg, &weights, &regs, 3).unwrap();
        let a = mc1.run_batch(&samples);
        let b = mc3.run_batch(&samples);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.counts, y.counts);
            assert_eq!(x.prediction, y.prediction);
        }
    }

    #[test]
    fn results_in_input_order() {
        let (cfg, weights, regs, samples) = setup();
        let mut mc = MultiCore::new(&cfg, &weights, &regs, 2).unwrap();
        let out = mc.run_batch(&samples);
        assert_eq!(out.len(), samples.len());
    }

    #[test]
    fn zero_cores_rejected() {
        let (cfg, weights, regs, _) = setup();
        assert!(MultiCore::new(&cfg, &weights, &regs, 0).is_err());
    }
}
