//! Versioned binary **connectome** snapshots: the complete state of a
//! [`ServingEngine`](super::serving::ServingEngine) — geometry, topology
//! stores (packed weight words), per-layer register files, the SoA neuron
//! bank (`vmem`/`refcnt`, plus the lane-major banks when `lane_width > 1`),
//! config epoch, and the Bus/Activity ledgers — as one self-describing,
//! CRC-protected byte stream.
//!
//! This is the durable half of the paper's software-defined methodology:
//! §II makes all core state programmatically readable/writable through
//! cfg_in/wt_in; a connectome file is that same state captured at a
//! quiesce point, so an engine can be checkpointed, restored bit-exactly
//! into a fresh process ([`ServingEngine::from_connectome`]), or
//! warm-swapped into a *live* engine as exactly one config epoch
//! ([`ControlPlane::migrate`](super::control::ControlPlane::migrate) —
//! drainless blue/green migration).
//!
//! # Format
//!
//! Everything is little-endian. The file is a fixed header followed by
//! TLV sections, each integrity-checked by a CRC-32 over its payload:
//!
//! ```text
//! magic   u32   "QCNX"
//! version u16   format version (1)
//! count   u32   number of sections
//! section * count:
//!   tag   u8    1 = geometry, 2 = ledgers, 3 = layer
//!   len   u32   payload byte length
//!   payload [len bytes]
//!   crc   u32   CRC-32 (IEEE) of payload
//! ```
//!
//! Section order is fixed: one GEOMETRY, one LEDGERS, then exactly
//! `cores × num_layers` LAYER sections in (shard-major, layer) order.
//! The decoder never panics: every read is bounds-checked through a
//! cursor in the style of `wire.rs`, every structural invariant maps to
//! a typed [`SnapshotError`], and corrupt input can never yield a
//! partially-restored engine (decoding is pure; application happens only
//! after the whole file validates).

use crate::config::model::MemKind;
use crate::config::registers::{RegisterFile, NUM_REGS};
use crate::config::Topology;
use crate::coordinator::interface::BusStats;
use crate::fixed::QSpec;
use crate::hdl::ActivityStats;

/// `b"QCNX"` little-endian: Quantisenc CoNnectome eXchange.
pub const MAGIC: u32 = u32::from_le_bytes(*b"QCNX");
/// Current format version.
pub const VERSION: u16 = 1;

const TAG_GEOMETRY: u8 = 1;
const TAG_LEDGERS: u8 = 2;
const TAG_LAYER: u8 = 3;

/// Hard cap on any single decoded vector arity (weights, vmem, …):
/// matches the wire layer's 16 MiB frame bound expressed in words, so a
/// hostile length field cannot drive a multi-GiB allocation.
const MAX_WORDS: usize = 16 * 1024 * 1024 / 4;

// ---------------------------------------------------------------------------
// CRC-32 (IEEE 802.3, reflected, poly 0xEDB88320) — table built in const fn
// so the dependency-free build pays nothing at runtime.

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc32_table();

/// Incremental CRC-32 (IEEE 802.3) digest: feed bytes in any chunking via
/// [`Crc32::update`] and read the checksum with [`Crc32::finish`]. Useful
/// when a payload is produced piecewise (streamed sections, scatter
/// buffers) — the digest over the concatenation equals the one-shot
/// [`crc32`] of the same bytes regardless of split points.
#[derive(Debug, Clone, Copy)]
pub struct Crc32 {
    state: u32,
}

impl Crc32 {
    /// A fresh digest (equivalent to having hashed zero bytes).
    pub fn new() -> Crc32 {
        Crc32 { state: 0xFFFF_FFFF }
    }

    /// Absorb a chunk; chunk boundaries do not affect the checksum.
    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state = CRC_TABLE[((self.state ^ b as u32) & 0xFF) as usize] ^ (self.state >> 8);
        }
    }

    /// The CRC-32 of everything absorbed so far. Non-consuming: further
    /// `update` calls continue the same running digest.
    pub fn finish(&self) -> u32 {
        !self.state
    }
}

impl Default for Crc32 {
    fn default() -> Crc32 {
        Crc32::new()
    }
}

/// CRC-32 (IEEE) of `bytes` in one shot (see [`Crc32`] for streaming).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut digest = Crc32::new();
    digest.update(bytes);
    digest.finish()
}

// ---------------------------------------------------------------------------
// Errors

/// Typed decode/validation failure. Corrupt or hostile snapshot bytes
/// always land on one of these — never a panic, never a partial restore.
#[derive(Debug, PartialEq, Eq)]
pub enum SnapshotError {
    /// Ran out of bytes while reading `what`.
    Truncated { what: &'static str },
    /// First word was not [`MAGIC`].
    BadMagic(u32),
    /// Unknown format version.
    BadVersion(u16),
    /// Payload CRC mismatch in the `index`-th section (`section` names its tag).
    BadCrc { section: &'static str, index: usize },
    /// A structural invariant failed (named by the message).
    BadValue(&'static str),
    /// Bytes left over after the declared sections.
    TrailingBytes { extra: usize },
}

impl std::fmt::Display for SnapshotError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SnapshotError::Truncated { what } => {
                write!(f, "truncated connectome: ran out of bytes reading {what}")
            }
            SnapshotError::BadMagic(m) => {
                write!(f, "bad connectome magic {m:#010x} (want {MAGIC:#010x} = \"QCNX\")")
            }
            SnapshotError::BadVersion(v) => {
                write!(f, "unsupported connectome format version {v} (decoder speaks {VERSION})")
            }
            SnapshotError::BadCrc { section, index } => {
                write!(f, "CRC mismatch in {section} section #{index} (corrupt payload)")
            }
            SnapshotError::BadValue(what) => write!(f, "invalid connectome: {what}"),
            SnapshotError::TrailingBytes { extra } => {
                write!(f, "{extra} trailing bytes after the last connectome section")
            }
        }
    }
}

impl std::error::Error for SnapshotError {}

// ---------------------------------------------------------------------------
// Bounds-checked cursor (wire.rs idiom; no index arithmetic can panic).

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize, what: &'static str) -> Result<&'a [u8], SnapshotError> {
        if self.remaining() < n {
            return Err(SnapshotError::Truncated { what });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self, what: &'static str) -> Result<u8, SnapshotError> {
        Ok(self.take(1, what)?[0])
    }

    fn u16(&mut self, what: &'static str) -> Result<u16, SnapshotError> {
        let b = self.take(2, what)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self, what: &'static str) -> Result<u32, SnapshotError> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn i32(&mut self, what: &'static str) -> Result<i32, SnapshotError> {
        Ok(self.u32(what)? as i32)
    }

    fn u64(&mut self, what: &'static str) -> Result<u64, SnapshotError> {
        let b = self.take(8, what)?;
        let mut v = [0u8; 8];
        v.copy_from_slice(b);
        Ok(u64::from_le_bytes(v))
    }

    /// `u32` count followed by that many `i32` words, with the count
    /// validated against the bytes actually present *and* [`MAX_WORDS`]
    /// before any allocation.
    fn i32_vec(&mut self, what: &'static str) -> Result<Vec<i32>, SnapshotError> {
        let n = self.u32(what)? as usize;
        if n > MAX_WORDS || self.remaining() / 4 < n {
            return Err(SnapshotError::Truncated { what });
        }
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.i32(what)?);
        }
        Ok(v)
    }
}

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_i32_vec(out: &mut Vec<u8>, v: &[i32]) {
    put_u32(out, v.len() as u32);
    for &w in v {
        put_u32(out, w as u32);
    }
}

// ---------------------------------------------------------------------------
// The in-memory snapshot

/// Per-(shard, layer) state captured at a quiesce point.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerState {
    /// The layer's register file ([`crate::config::registers::RegisterFile::vector`]).
    /// Registers are broadcast engine-wide, so every section carries the
    /// same vector; restore validates that invariant.
    pub regs: [i32; NUM_REGS],
    /// Topology-aware packed weight words
    /// ([`crate::hdl::SynapticMemory::packed`]) — dense words for
    /// all-to-all, the diagonal for one-to-one, the band for gaussian.
    pub weights: Vec<i32>,
    /// Single-sample membrane potentials (one word per neuron).
    pub vmem: Vec<i32>,
    /// Single-sample refractory countdowns (one word per neuron).
    pub refcnt: Vec<i32>,
    /// Lane count the lane-major banks were sized for (0 if the
    /// lane-batched datapath never ran on this shard).
    pub lanes: u16,
    /// Lane-major membrane bank: `lane_vmem[j * lanes + l]`.
    pub lane_vmem: Vec<i32>,
    /// Lane-major refractory bank, same layout.
    pub lane_refcnt: Vec<i32>,
}

impl LayerState {
    /// Materialize this section's register vector as a live
    /// [`RegisterFile`] — the seed a supervised shard rebuild spawns its
    /// stage chain under (registers are broadcast engine-wide, so any one
    /// section's vector is the whole engine's). Register values captured
    /// from a live engine always re-apply cleanly; an error here means the
    /// snapshot was hand-forged out of range.
    pub fn register_file(
        &self,
        qspec: QSpec,
    ) -> Result<RegisterFile, crate::config::registers::RegisterError> {
        let mut regs = RegisterFile::new(qspec);
        let program: Vec<(usize, i32)> = self.regs.iter().copied().enumerate().collect();
        regs.apply_program(&program)?;
        Ok(regs)
    }
}

/// A complete, self-describing engine snapshot. Produced by
/// [`ServingEngine::snapshot`](super::serving::ServingEngine::snapshot),
/// serialized by [`Connectome::encode`], revived by
/// [`Connectome::decode`] +
/// [`ServingEngine::from_connectome`](super::serving::ServingEngine::from_connectome).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Connectome {
    pub qspec: QSpec,
    pub mem: MemKind,
    /// Shard count C of the source engine.
    pub cores: u16,
    /// Samples stepped per lane group (1 = single-sample datapath).
    pub lane_width: u16,
    /// Layer widths, inputs first (`sizes.len() >= 2`).
    pub sizes: Vec<u32>,
    /// One topology per connection layer (`sizes.len() - 1` entries).
    pub topologies: Vec<Topology>,
    /// Config epoch at the quiesce point.
    pub epoch: u64,
    /// Engine-wide AXI bus ledger at the quiesce point.
    pub bus: BusStats,
    /// Cumulative activity ledger across all completed streams.
    pub activity: ActivityStats,
    /// Streams admitted by the source engine.
    pub submitted: u64,
    /// Streams fully served. Equal to `submitted` at a quiesce point —
    /// the snapshot fences at a sample-group boundary, so there are no
    /// partially-stepped streams to record; this pair *is* the ragged
    /// in-flight position ledger.
    pub completed: u64,
    /// `[shard][layer]` state sections.
    pub layers: Vec<Vec<LayerState>>,
}

impl Connectome {
    /// Serialize to the versioned TLV byte format described in the
    /// module docs. Infallible: the encoder only runs on snapshots
    /// produced from live engine state, whose arities are bounded far
    /// below the format's `u32` limits.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        put_u32(&mut out, MAGIC);
        put_u16(&mut out, VERSION);
        let n_layer_sections: usize = self.layers.iter().map(Vec::len).sum();
        put_u32(&mut out, 2 + n_layer_sections as u32);

        // GEOMETRY
        let mut p = Vec::new();
        p.push(self.qspec.n());
        p.push(self.qspec.q());
        p.push(mem_tag(self.mem));
        put_u16(&mut p, self.cores);
        put_u16(&mut p, self.lane_width);
        put_u32(&mut p, self.sizes.len() as u32);
        for &s in &self.sizes {
            put_u32(&mut p, s);
        }
        for t in &self.topologies {
            let (tag, radius) = match t {
                Topology::AllToAll => (0u8, 0u32),
                Topology::OneToOne => (1, 0),
                Topology::Gaussian { radius } => (2, *radius),
            };
            p.push(tag);
            put_u32(&mut p, radius);
        }
        put_section(&mut out, TAG_GEOMETRY, &p);

        // LEDGERS
        let mut p = Vec::new();
        put_u64(&mut p, self.epoch);
        for v in [
            self.bus.wt_writes,
            self.bus.cfg_writes,
            self.bus.spk_in_events,
            self.bus.spk_out_events,
        ] {
            put_u64(&mut p, v);
        }
        for v in [
            self.activity.spk_steps,
            self.activity.mem_cycles,
            self.activity.synaptic_ops,
            self.activity.gated_ops,
            self.activity.vmem_toggles,
            self.activity.neuron_updates,
            self.activity.spikes,
        ] {
            put_u64(&mut p, v);
        }
        put_u64(&mut p, self.submitted);
        put_u64(&mut p, self.completed);
        put_section(&mut out, TAG_LEDGERS, &p);

        // LAYER sections, shard-major.
        for (shard, states) in self.layers.iter().enumerate() {
            for (layer, st) in states.iter().enumerate() {
                let mut p = Vec::new();
                put_u16(&mut p, shard as u16);
                put_u16(&mut p, layer as u16);
                for &r in &st.regs {
                    put_u32(&mut p, r as u32);
                }
                put_i32_vec(&mut p, &st.weights);
                put_i32_vec(&mut p, &st.vmem);
                put_i32_vec(&mut p, &st.refcnt);
                put_u16(&mut p, st.lanes);
                put_i32_vec(&mut p, &st.lane_vmem);
                put_i32_vec(&mut p, &st.lane_refcnt);
                put_section(&mut out, TAG_LAYER, &p);
            }
        }
        out
    }

    /// Decode and structurally validate a connectome. Every byte is read
    /// through the bounds-checked cursor; every section payload must match
    /// its CRC; geometry invariants (layer arity, bank sizes vs neuron
    /// counts, section order) are enforced here so downstream consumers
    /// can index freely. Hostile input yields a typed [`SnapshotError`],
    /// never a panic and never an allocation larger than the input could
    /// justify.
    pub fn decode(bytes: &[u8]) -> Result<Connectome, SnapshotError> {
        let mut c = Cursor::new(bytes);
        let magic = c.u32("magic")?;
        if magic != MAGIC {
            return Err(SnapshotError::BadMagic(magic));
        }
        let version = c.u16("version")?;
        if version != VERSION {
            return Err(SnapshotError::BadVersion(version));
        }
        let count = c.u32("section count")? as usize;
        if count < 2 {
            return Err(SnapshotError::BadValue("fewer than two sections"));
        }

        let mut geometry: Option<Vec<u8>> = None;
        let mut ledgers: Option<Vec<u8>> = None;
        let mut layer_payloads: Vec<Vec<u8>> = Vec::new();
        for index in 0..count {
            let tag = c.u8("section tag")?;
            let len = c.u32("section length")? as usize;
            let payload = c.take(len, "section payload")?;
            let crc = c.u32("section crc")?;
            if crc32(payload) != crc {
                let section = match tag {
                    TAG_GEOMETRY => "geometry",
                    TAG_LEDGERS => "ledgers",
                    TAG_LAYER => "layer",
                    _ => "unknown",
                };
                return Err(SnapshotError::BadCrc { section, index });
            }
            match tag {
                TAG_GEOMETRY if index == 0 && geometry.is_none() => {
                    geometry = Some(payload.to_vec());
                }
                TAG_LEDGERS if index == 1 && ledgers.is_none() => {
                    ledgers = Some(payload.to_vec());
                }
                TAG_LAYER if index >= 2 => layer_payloads.push(payload.to_vec()),
                TAG_GEOMETRY | TAG_LEDGERS | TAG_LAYER => {
                    return Err(SnapshotError::BadValue("sections out of order"));
                }
                _ => return Err(SnapshotError::BadValue("unknown section tag")),
            }
        }
        if c.remaining() != 0 {
            return Err(SnapshotError::TrailingBytes { extra: c.remaining() });
        }
        let geometry = geometry.ok_or(SnapshotError::BadValue("missing geometry section"))?;
        let ledgers = ledgers.ok_or(SnapshotError::BadValue("missing ledgers section"))?;

        // GEOMETRY
        let mut g = Cursor::new(&geometry);
        let n = g.u8("qspec n")?;
        let q = g.u8("qspec q")?;
        let qspec =
            QSpec::new(n, q).map_err(|_| SnapshotError::BadValue("qspec out of range"))?;
        let mem = mem_from_tag(g.u8("memory kind")?)
            .ok_or(SnapshotError::BadValue("unknown memory kind"))?;
        let cores = g.u16("core count")?;
        if cores == 0 {
            return Err(SnapshotError::BadValue("zero cores"));
        }
        let lane_width = g.u16("lane width")?;
        if lane_width == 0 || lane_width > 64 {
            return Err(SnapshotError::BadValue("lane width outside 1..=64"));
        }
        let n_sizes = g.u32("layer-size count")? as usize;
        if !(2..=1024).contains(&n_sizes) {
            return Err(SnapshotError::BadValue("layer-size count outside 2..=1024"));
        }
        let mut sizes = Vec::with_capacity(n_sizes);
        for _ in 0..n_sizes {
            let s = g.u32("layer size")?;
            if s == 0 || s as usize > MAX_WORDS {
                return Err(SnapshotError::BadValue("layer size outside 1..=4Mi"));
            }
            sizes.push(s);
        }
        let mut topologies = Vec::with_capacity(n_sizes - 1);
        for _ in 0..n_sizes - 1 {
            let tag = g.u8("topology tag")?;
            let radius = g.u32("topology radius")?;
            topologies.push(match tag {
                0 => Topology::AllToAll,
                1 => Topology::OneToOne,
                2 => Topology::Gaussian { radius },
                _ => return Err(SnapshotError::BadValue("unknown topology tag")),
            });
        }
        if g.remaining() != 0 {
            return Err(SnapshotError::BadValue("geometry section has trailing bytes"));
        }

        // LEDGERS
        let mut l = Cursor::new(&ledgers);
        let epoch = l.u64("epoch")?;
        let bus = BusStats {
            wt_writes: l.u64("wt_writes")?,
            cfg_writes: l.u64("cfg_writes")?,
            spk_in_events: l.u64("spk_in_events")?,
            spk_out_events: l.u64("spk_out_events")?,
        };
        let activity = ActivityStats {
            spk_steps: l.u64("spk_steps")?,
            mem_cycles: l.u64("mem_cycles")?,
            synaptic_ops: l.u64("synaptic_ops")?,
            gated_ops: l.u64("gated_ops")?,
            vmem_toggles: l.u64("vmem_toggles")?,
            neuron_updates: l.u64("neuron_updates")?,
            spikes: l.u64("spikes")?,
        };
        let submitted = l.u64("submitted")?;
        let completed = l.u64("completed")?;
        if l.remaining() != 0 {
            return Err(SnapshotError::BadValue("ledgers section has trailing bytes"));
        }

        // LAYER sections: exactly cores × (sizes.len()-1), shard-major.
        let num_layers = n_sizes - 1;
        if layer_payloads.len() != cores as usize * num_layers {
            return Err(SnapshotError::BadValue("layer section count != cores x layers"));
        }
        let mut layers: Vec<Vec<LayerState>> = Vec::with_capacity(cores as usize);
        let mut payloads = layer_payloads.iter();
        for shard in 0..cores {
            let mut states = Vec::with_capacity(num_layers);
            for layer in 0..num_layers {
                let payload = payloads.next().expect("arity checked above");
                let mut s = Cursor::new(payload);
                if s.u16("shard index")? != shard || s.u16("layer index")? != layer as u16 {
                    return Err(SnapshotError::BadValue("layer section out of order"));
                }
                let mut regs = [0i32; NUM_REGS];
                for r in &mut regs {
                    *r = s.i32("register value")?;
                }
                let weights = s.i32_vec("weight words")?;
                let vmem = s.i32_vec("vmem bank")?;
                let refcnt = s.i32_vec("refcnt bank")?;
                let lanes = s.u16("lane count")?;
                let lane_vmem = s.i32_vec("lane vmem bank")?;
                let lane_refcnt = s.i32_vec("lane refcnt bank")?;
                if s.remaining() != 0 {
                    return Err(SnapshotError::BadValue("layer section has trailing bytes"));
                }
                let neurons = sizes[layer + 1] as usize;
                if vmem.len() != neurons || refcnt.len() != neurons {
                    return Err(SnapshotError::BadValue("neuron bank size != layer width"));
                }
                if lanes > 64 {
                    return Err(SnapshotError::BadValue("lane bank wider than 64"));
                }
                let lane_words = neurons * lanes as usize;
                if lane_vmem.len() != lane_words || lane_refcnt.len() != lane_words {
                    return Err(SnapshotError::BadValue("lane bank size != width x lanes"));
                }
                states.push(LayerState {
                    regs,
                    weights,
                    vmem,
                    refcnt,
                    lanes,
                    lane_vmem,
                    lane_refcnt,
                });
            }
            layers.push(states);
        }

        Ok(Connectome {
            qspec,
            mem,
            cores,
            lane_width,
            sizes,
            topologies,
            epoch,
            bus,
            activity,
            submitted,
            completed,
            layers,
        })
    }

    /// The engine-wide register vector. Registers are broadcast to every
    /// shard and layer, so all sections must agree; a snapshot that
    /// disagrees with itself is rejected rather than silently picking one.
    pub fn register_vector(&self) -> Result<[i32; NUM_REGS], SnapshotError> {
        let first = self
            .layers
            .first()
            .and_then(|s| s.first())
            .ok_or(SnapshotError::BadValue("no layer sections"))?
            .regs;
        for states in &self.layers {
            for st in states {
                if st.regs != first {
                    return Err(SnapshotError::BadValue("register sections disagree"));
                }
            }
        }
        Ok(first)
    }
}

fn put_section(out: &mut Vec<u8>, tag: u8, payload: &[u8]) {
    out.push(tag);
    put_u32(out, payload.len() as u32);
    out.extend_from_slice(payload);
    put_u32(out, crc32(payload));
}

fn mem_tag(mem: MemKind) -> u8 {
    match mem {
        MemKind::Bram => 0,
        MemKind::DistributedLut => 1,
        MemKind::Register => 2,
    }
}

fn mem_from_tag(tag: u8) -> Option<MemKind> {
    match tag {
        0 => Some(MemKind::Bram),
        1 => Some(MemKind::DistributedLut),
        2 => Some(MemKind::Register),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_reference_vectors() {
        // The canonical IEEE check value plus an empty-input identity.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(Crc32::new().finish(), 0, "fresh digest = empty-input identity");
    }

    #[test]
    fn incremental_crc32_matches_one_shot_at_every_split() {
        // Chunk boundaries must never affect the digest: hash a buffer at
        // every possible split point (including empty chunks) and compare
        // against the one-shot CRC of the whole.
        let data: Vec<u8> = (0u32..300).map(|i| (i.wrapping_mul(31) ^ (i >> 3)) as u8).collect();
        let whole = crc32(&data);
        for split in 0..=data.len() {
            let mut digest = Crc32::new();
            digest.update(&data[..split]);
            digest.update(&[]);
            digest.update(&data[split..]);
            assert_eq!(digest.finish(), whole, "split at {split} changed the digest");
        }
    }

    fn tiny() -> Connectome {
        Connectome {
            qspec: crate::fixed::Q5_3,
            mem: MemKind::Bram,
            cores: 1,
            lane_width: 1,
            sizes: vec![2, 3],
            topologies: vec![Topology::AllToAll],
            epoch: 7,
            bus: BusStats { wt_writes: 1, cfg_writes: 2, spk_in_events: 3, spk_out_events: 4 },
            activity: ActivityStats { spikes: 9, ..Default::default() },
            submitted: 5,
            completed: 5,
            layers: vec![vec![LayerState {
                regs: [2, 8, 8, 0, 2, 0],
                weights: vec![1, -2, 3, -4, 5, -6],
                vmem: vec![0, 0, 0],
                refcnt: vec![0, 0, 0],
                lanes: 0,
                lane_vmem: vec![],
                lane_refcnt: vec![],
            }]],
        }
    }

    #[test]
    fn roundtrip_is_identity() {
        let c = tiny();
        let bytes = c.encode();
        assert_eq!(Connectome::decode(&bytes).unwrap(), c);
    }

    #[test]
    fn every_truncation_is_a_typed_error() {
        let bytes = tiny().encode();
        for cut in 0..bytes.len() {
            let err = Connectome::decode(&bytes[..cut]);
            assert!(err.is_err(), "decode of {cut}-byte prefix must fail");
        }
    }

    #[test]
    fn register_disagreement_is_rejected() {
        let mut c = tiny();
        c.cores = 2;
        let mut other = c.layers[0].clone();
        other[0].regs[0] = 3;
        c.layers.push(other);
        let bytes = c.encode();
        let decoded = Connectome::decode(&bytes).unwrap();
        assert_eq!(
            decoded.register_vector(),
            Err(SnapshotError::BadValue("register sections disagree"))
        );
    }
}
