//! Binary spike-frame wire protocol — the network front door's frame
//! grammar (std-only, zero-dep, mirroring the transport/core split of
//! FEAGI's `feagi-transports` next to the neural core crates).
//!
//! The paper's hardware–software interface streams spikes onto the core
//! through three channels (spk_in, cfg_in, wt_in); this module is the
//! network twin of that interface: a compact, length-prefixed binary
//! framing that carries bit-packed spike trains ([`Frame::SubmitSample`]),
//! control-plane programs ([`Frame::Reconfig`] → cfg_in/wt_in), and their
//! results back ([`Frame::Result`]) over one TCP byte stream.
//!
//! ## Frame grammar
//!
//! Every frame on the wire is
//!
//! ```text
//! u32 LE  body length N (1 ..= max_frame_len)
//! u8      frame type (see the [`Frame`] discriminants)
//! ...     N-1 bytes of type-specific payload, all integers LE
//! ```
//!
//! | type | frame            | payload |
//! |------|------------------|---------|
//! | 1    | `Hello`          | magic `u32` (`QSNC`), version `u16` |
//! | 2    | `HelloAck`       | version `u16`, inputs `u32`, outputs `u32`, cores `u16`, lane_width `u16` |
//! | 3    | `OpenSession`    | requested max in-flight `u32` (0 = server default) |
//! | 4    | `SessionOpened`  | session `u32`, granted max in-flight `u32` |
//! | 5    | `SubmitSample`   | session `u32`, sample id `u64`, t_steps `u32`, inputs `u32`, bit-packed spikes `⌈t·i/8⌉` bytes |
//! | 6    | `Reconfig`       | session `u32`, request id `u64`, n_cfg `u16`, n_cfg × (addr `u16`, value `i32`), n_swap `u16`, n_swap × (layer `u16`, words `u32`, words × `i32`) |
//! | 7    | `Result`         | session `u32`, sample id `u64`, epoch `u64`, prediction `u32`, spikes_total `u64`, n_counts `u16`, n_counts × `u32` |
//! | 8    | `ReconfigAck`    | session `u32`, request id `u64`, epoch `u64` |
//! | 9    | `Error`          | code `u16`, session `u32`, reference id `u64`, msg_len `u16`, UTF-8 message |
//! | 10   | `Snapshot`       | session `u32`, request id `u64` |
//! | 11   | `SnapshotData`   | session `u32`, request id `u64`, byte_len `u32`, connectome bytes |
//! | 12   | `Restore`        | session `u32`, request id `u64`, byte_len `u32`, connectome bytes |
//! | 13   | `RestoreAck`     | session `u32`, request id `u64`, epoch `u64` |
//! | 14   | `HealthReq`      | request id `u64` |
//! | 15   | `Health`         | request id `u64`, degraded `u8`, recoveries `u64`, quarantines `u64`, checkpoint_age `u64`, scrubbed_blocks `u64`, corrected `u64`, detected `u64`, n_shards `u16`, n_shards × status `u8` (0 Healthy, 1 Quarantined, 2 Rebuilding) |
//!
//! Spike payloads are bit-packed row-major (timestep-major, LSB-first
//! within each byte) — the AER-flavoured dense encoding: 8 spike lines per
//! byte instead of one, so a 700-input SHD step is 88 bytes on the wire.
//!
//! ## Robustness contract
//!
//! Decoding NEVER panics and never trusts a length field it has not
//! checked against the bytes actually present: every read is
//! bounds-checked ([`WireError::Truncated`]), oversized frames are
//! rejected before allocation ([`WireError::TooLarge`]), undecoded
//! trailing bytes are an error ([`WireError::TrailingBytes`]), and all
//! rejections are typed [`WireError`]s — property/fuzz-tested in
//! `rust/tests/wire_protocol.rs` against random, truncated, and garbage
//! frames.

use std::io::{self, Read, Write};

use super::control::ReconfigProgram;
use crate::datasets::Sample;

/// First payload word of every [`Frame::Hello`]: `"QSNC"` little-endian.
pub const MAGIC: u32 = u32::from_le_bytes(*b"QSNC");

/// Protocol version spoken by this build.
pub const VERSION: u16 = 1;

/// Default cap on one frame's body length (16 MiB): large enough for a
/// full wt_in weight swap of any shipped model, small enough that a
/// hostile length prefix cannot balloon server memory.
pub const DEFAULT_MAX_FRAME_LEN: u32 = 16 * 1024 * 1024;

/// Typed rejection codes carried by [`Frame::Error`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// Admission control refused the sample: the session already has its
    /// full in-flight quota (or the server queue is full). Back off and
    /// resubmit; nothing was enqueued.
    Overloaded,
    /// The frame referenced a session id this connection never opened.
    BadSession,
    /// A `Reconfig` program failed control-plane validation; nothing was
    /// applied and no epoch was burned.
    BadProgram,
    /// A `SubmitSample` did not match the engine geometry (input width or
    /// timestep bounds).
    BadSample,
    /// The byte stream violated the frame grammar; the server closes the
    /// connection after sending this.
    BadFrame,
    /// The serving engine failed (e.g. a worker panicked). The process
    /// stays alive but this engine no longer serves.
    Internal,
    /// The connection sent no complete frame for longer than the server's
    /// configured idle read timeout; the server closes it after sending
    /// this (the slow-loris defence).
    IdleTimeout,
    /// The serving shard carrying this stream died mid-flight; the sample
    /// was lost but the engine is self-healing. Submits are idempotent, so
    /// the client may safely resubmit (the `RetryPolicy` does so
    /// automatically).
    ShardLost,
}

impl ErrorCode {
    pub fn as_u16(self) -> u16 {
        match self {
            ErrorCode::Overloaded => 1,
            ErrorCode::BadSession => 2,
            ErrorCode::BadProgram => 3,
            ErrorCode::BadSample => 4,
            ErrorCode::BadFrame => 5,
            ErrorCode::Internal => 6,
            ErrorCode::IdleTimeout => 7,
            ErrorCode::ShardLost => 8,
        }
    }

    pub fn from_u16(v: u16) -> Option<ErrorCode> {
        Some(match v {
            1 => ErrorCode::Overloaded,
            2 => ErrorCode::BadSession,
            3 => ErrorCode::BadProgram,
            4 => ErrorCode::BadSample,
            5 => ErrorCode::BadFrame,
            6 => ErrorCode::Internal,
            7 => ErrorCode::IdleTimeout,
            8 => ErrorCode::ShardLost,
            _ => return None,
        })
    }
}

/// One protocol frame (see the module-level grammar table).
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    Hello { version: u16 },
    HelloAck { version: u16, inputs: u32, outputs: u32, cores: u16, lane_width: u16 },
    OpenSession { max_inflight: u32 },
    SessionOpened { session: u32, max_inflight: u32 },
    /// One spike-train sample: `spikes` is the bit-packed row-major
    /// `t_steps × inputs` binary matrix (LSB-first), exactly
    /// `(t_steps * inputs + 7) / 8` bytes.
    SubmitSample { session: u32, sample: u64, t_steps: u32, inputs: u32, spikes: Vec<u8> },
    Reconfig { session: u32, request: u64, cfg: Vec<(u16, i32)>, weights: Vec<(u16, Vec<i32>)> },
    Result {
        session: u32,
        sample: u64,
        epoch: u64,
        prediction: u32,
        spikes_total: u64,
        counts: Vec<u32>,
    },
    ReconfigAck { session: u32, request: u64, epoch: u64 },
    Error { code: ErrorCode, session: u32, reference: u64, message: String },
    /// Request a connectome snapshot of the engine (taken at the pump's
    /// next sample-group boundary; see `coordinator::connectome`).
    Snapshot { session: u32, request: u64 },
    /// A snapshot's encoded connectome, answering a `Snapshot` request.
    SnapshotData { session: u32, request: u64, bytes: Vec<u8> },
    /// Offer a connectome for live blue/green migration: its registers +
    /// weights are applied to the serving engine as one config epoch.
    Restore { session: u32, request: u64, bytes: Vec<u8> },
    /// Migration applied; `epoch` is the config epoch it was assigned.
    RestoreAck { session: u32, request: u64, epoch: u64 },
    /// Ask the server for its supervision state (answered out of the
    /// pump's telemetry mirror — never blocks on the engine).
    HealthReq { request: u64 },
    /// Supervision state: `degraded` is true while any shard is not
    /// healthy, `shards` carries one status byte per shard (0 Healthy,
    /// 1 Quarantined, 2 Rebuilding), `checkpoint_age` is samples
    /// completed since the live recovery point was fenced. The integrity
    /// triple mirrors the engine's memory-integrity ledger: parity/SECDED
    /// blocks swept by the background scrubber, single-bit upsets repaired
    /// in place, and detected-uncorrectable words (quarantine causes).
    Health {
        request: u64,
        degraded: bool,
        recoveries: u64,
        quarantines: u64,
        checkpoint_age: u64,
        scrubbed_blocks: u64,
        corrected: u64,
        detected: u64,
        shards: Vec<u8>,
    },
}

/// Typed decode/transport failure. Every malformed input maps here — the
/// codec never panics on wire data.
#[derive(Debug)]
pub enum WireError {
    /// Transport-level I/O failure (includes read timeouts).
    Io(io::Error),
    /// The byte stream ended (or the frame body ran out) mid-field.
    Truncated { what: &'static str },
    /// A length prefix exceeded the configured frame cap.
    TooLarge { len: u32, max: u32 },
    /// A frame body decoded cleanly but left undecoded bytes behind.
    TrailingBytes { frame: &'static str, extra: usize },
    /// Unknown frame type byte.
    BadType(u8),
    /// A `Hello` carried the wrong magic word.
    BadMagic(u32),
    /// A field held a value outside its domain (bad error code, bit-pack
    /// arity mismatch, non-UTF-8 message, ...).
    BadValue(&'static str),
    /// The socket was idle past its read timeout *between* frames — not a
    /// protocol violation; callers poll their shutdown flag and retry.
    Idle,
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "wire i/o error: {e}"),
            WireError::Truncated { what } => write!(f, "truncated frame: {what}"),
            WireError::TooLarge { len, max } => {
                write!(f, "frame length {len} exceeds the {max}-byte cap")
            }
            WireError::TrailingBytes { frame, extra } => {
                write!(f, "{frame} frame has {extra} trailing bytes")
            }
            WireError::BadType(t) => write!(f, "unknown frame type {t:#04x}"),
            WireError::BadMagic(m) => {
                write!(f, "bad hello magic {m:#010x} (expected {MAGIC:#010x})")
            }
            WireError::BadValue(what) => write!(f, "bad field value: {what}"),
            WireError::Idle => write!(f, "socket idle past its read timeout"),
        }
    }
}

impl std::error::Error for WireError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            WireError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<io::Error> for WireError {
    fn from(e: io::Error) -> WireError {
        WireError::Io(e)
    }
}

/// Bounds-checked little-endian reader over one frame body.
struct Cursor<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(b: &'a [u8]) -> Cursor<'a> {
        Cursor { b, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.b.len() - self.pos
    }

    fn take(&mut self, n: usize, what: &'static str) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(WireError::Truncated { what });
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self, what: &'static str) -> Result<u8, WireError> {
        Ok(self.take(1, what)?[0])
    }

    fn u16(&mut self, what: &'static str) -> Result<u16, WireError> {
        let s = self.take(2, what)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }

    fn u32(&mut self, what: &'static str) -> Result<u32, WireError> {
        let s = self.take(4, what)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn i32(&mut self, what: &'static str) -> Result<i32, WireError> {
        Ok(self.u32(what)? as i32)
    }

    fn u64(&mut self, what: &'static str) -> Result<u64, WireError> {
        let s = self.take(8, what)?;
        let mut b = [0u8; 8];
        b.copy_from_slice(s);
        Ok(u64::from_le_bytes(b))
    }
}

impl Frame {
    /// Human-readable frame name (diagnostics and trailing-byte errors).
    pub fn name(&self) -> &'static str {
        match self {
            Frame::Hello { .. } => "Hello",
            Frame::HelloAck { .. } => "HelloAck",
            Frame::OpenSession { .. } => "OpenSession",
            Frame::SessionOpened { .. } => "SessionOpened",
            Frame::SubmitSample { .. } => "SubmitSample",
            Frame::Reconfig { .. } => "Reconfig",
            Frame::Result { .. } => "Result",
            Frame::ReconfigAck { .. } => "ReconfigAck",
            Frame::Error { .. } => "Error",
            Frame::Snapshot { .. } => "Snapshot",
            Frame::SnapshotData { .. } => "SnapshotData",
            Frame::Restore { .. } => "Restore",
            Frame::RestoreAck { .. } => "RestoreAck",
            Frame::HealthReq { .. } => "HealthReq",
            Frame::Health { .. } => "Health",
        }
    }

    fn type_byte(&self) -> u8 {
        match self {
            Frame::Hello { .. } => 1,
            Frame::HelloAck { .. } => 2,
            Frame::OpenSession { .. } => 3,
            Frame::SessionOpened { .. } => 4,
            Frame::SubmitSample { .. } => 5,
            Frame::Reconfig { .. } => 6,
            Frame::Result { .. } => 7,
            Frame::ReconfigAck { .. } => 8,
            Frame::Error { .. } => 9,
            Frame::Snapshot { .. } => 10,
            Frame::SnapshotData { .. } => 11,
            Frame::Restore { .. } => 12,
            Frame::RestoreAck { .. } => 13,
            Frame::HealthReq { .. } => 14,
            Frame::Health { .. } => 15,
        }
    }

    /// Serialize this frame's body (everything after the length prefix).
    /// Encoding is infallible for frames built through the typed API;
    /// arity overflows (> u16::MAX cfg writes, counts, ...) are reported
    /// as [`WireError::BadValue`] instead of being silently truncated.
    pub fn encode(&self) -> Result<Vec<u8>, WireError> {
        let mut out = Vec::with_capacity(16);
        out.push(self.type_byte());
        match self {
            Frame::Hello { version } => {
                out.extend_from_slice(&MAGIC.to_le_bytes());
                out.extend_from_slice(&version.to_le_bytes());
            }
            Frame::HelloAck { version, inputs, outputs, cores, lane_width } => {
                out.extend_from_slice(&version.to_le_bytes());
                out.extend_from_slice(&inputs.to_le_bytes());
                out.extend_from_slice(&outputs.to_le_bytes());
                out.extend_from_slice(&cores.to_le_bytes());
                out.extend_from_slice(&lane_width.to_le_bytes());
            }
            Frame::OpenSession { max_inflight } => {
                out.extend_from_slice(&max_inflight.to_le_bytes());
            }
            Frame::SessionOpened { session, max_inflight } => {
                out.extend_from_slice(&session.to_le_bytes());
                out.extend_from_slice(&max_inflight.to_le_bytes());
            }
            Frame::SubmitSample { session, sample, t_steps, inputs, spikes } => {
                let expect = packed_len(*t_steps as u64 * *inputs as u64);
                if spikes.len() as u64 != expect {
                    return Err(WireError::BadValue("spike payload arity"));
                }
                out.extend_from_slice(&session.to_le_bytes());
                out.extend_from_slice(&sample.to_le_bytes());
                out.extend_from_slice(&t_steps.to_le_bytes());
                out.extend_from_slice(&inputs.to_le_bytes());
                out.extend_from_slice(spikes);
            }
            Frame::Reconfig { session, request, cfg, weights } => {
                if cfg.len() > u16::MAX as usize || weights.len() > u16::MAX as usize {
                    return Err(WireError::BadValue("reconfig arity"));
                }
                out.extend_from_slice(&session.to_le_bytes());
                out.extend_from_slice(&request.to_le_bytes());
                out.extend_from_slice(&(cfg.len() as u16).to_le_bytes());
                for (addr, value) in cfg {
                    out.extend_from_slice(&addr.to_le_bytes());
                    out.extend_from_slice(&value.to_le_bytes());
                }
                out.extend_from_slice(&(weights.len() as u16).to_le_bytes());
                for (layer, payload) in weights {
                    if payload.len() > u32::MAX as usize {
                        return Err(WireError::BadValue("weight payload arity"));
                    }
                    out.extend_from_slice(&layer.to_le_bytes());
                    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
                    for w in payload {
                        out.extend_from_slice(&w.to_le_bytes());
                    }
                }
            }
            Frame::Result { session, sample, epoch, prediction, spikes_total, counts } => {
                if counts.len() > u16::MAX as usize {
                    return Err(WireError::BadValue("counts arity"));
                }
                out.extend_from_slice(&session.to_le_bytes());
                out.extend_from_slice(&sample.to_le_bytes());
                out.extend_from_slice(&epoch.to_le_bytes());
                out.extend_from_slice(&prediction.to_le_bytes());
                out.extend_from_slice(&spikes_total.to_le_bytes());
                out.extend_from_slice(&(counts.len() as u16).to_le_bytes());
                for c in counts {
                    out.extend_from_slice(&c.to_le_bytes());
                }
            }
            Frame::ReconfigAck { session, request, epoch } => {
                out.extend_from_slice(&session.to_le_bytes());
                out.extend_from_slice(&request.to_le_bytes());
                out.extend_from_slice(&epoch.to_le_bytes());
            }
            Frame::Error { code, session, reference, message } => {
                let msg = message.as_bytes();
                if msg.len() > u16::MAX as usize {
                    return Err(WireError::BadValue("error message length"));
                }
                out.extend_from_slice(&code.as_u16().to_le_bytes());
                out.extend_from_slice(&session.to_le_bytes());
                out.extend_from_slice(&reference.to_le_bytes());
                out.extend_from_slice(&(msg.len() as u16).to_le_bytes());
                out.extend_from_slice(msg);
            }
            Frame::Snapshot { session, request } => {
                out.extend_from_slice(&session.to_le_bytes());
                out.extend_from_slice(&request.to_le_bytes());
            }
            Frame::SnapshotData { session, request, bytes }
            | Frame::Restore { session, request, bytes } => {
                if bytes.len() > u32::MAX as usize {
                    return Err(WireError::BadValue("connectome payload arity"));
                }
                out.extend_from_slice(&session.to_le_bytes());
                out.extend_from_slice(&request.to_le_bytes());
                out.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
                out.extend_from_slice(bytes);
            }
            Frame::RestoreAck { session, request, epoch } => {
                out.extend_from_slice(&session.to_le_bytes());
                out.extend_from_slice(&request.to_le_bytes());
                out.extend_from_slice(&epoch.to_le_bytes());
            }
            Frame::HealthReq { request } => {
                out.extend_from_slice(&request.to_le_bytes());
            }
            Frame::Health {
                request,
                degraded,
                recoveries,
                quarantines,
                checkpoint_age,
                scrubbed_blocks,
                corrected,
                detected,
                shards,
            } => {
                if shards.len() > u16::MAX as usize {
                    return Err(WireError::BadValue("shard status arity"));
                }
                out.extend_from_slice(&request.to_le_bytes());
                out.push(*degraded as u8);
                out.extend_from_slice(&recoveries.to_le_bytes());
                out.extend_from_slice(&quarantines.to_le_bytes());
                out.extend_from_slice(&checkpoint_age.to_le_bytes());
                out.extend_from_slice(&scrubbed_blocks.to_le_bytes());
                out.extend_from_slice(&corrected.to_le_bytes());
                out.extend_from_slice(&detected.to_le_bytes());
                out.extend_from_slice(&(shards.len() as u16).to_le_bytes());
                out.extend_from_slice(shards);
            }
        }
        Ok(out)
    }

    /// Decode one frame body (the bytes after the length prefix). Every
    /// failure is a typed [`WireError`]; this function never panics on
    /// arbitrary input and never allocates more than the body it was
    /// handed (counts are validated against the bytes actually present
    /// before any buffer is sized from them).
    pub fn decode(body: &[u8]) -> Result<Frame, WireError> {
        let mut c = Cursor::new(body);
        let t = c.u8("frame type")?;
        let frame = match t {
            1 => {
                let magic = c.u32("hello magic")?;
                if magic != MAGIC {
                    return Err(WireError::BadMagic(magic));
                }
                Frame::Hello { version: c.u16("hello version")? }
            }
            2 => Frame::HelloAck {
                version: c.u16("helloack version")?,
                inputs: c.u32("helloack inputs")?,
                outputs: c.u32("helloack outputs")?,
                cores: c.u16("helloack cores")?,
                lane_width: c.u16("helloack lane width")?,
            },
            3 => Frame::OpenSession { max_inflight: c.u32("open max_inflight")? },
            4 => Frame::SessionOpened {
                session: c.u32("opened session")?,
                max_inflight: c.u32("opened max_inflight")?,
            },
            5 => {
                let session = c.u32("submit session")?;
                let sample = c.u64("submit sample id")?;
                let t_steps = c.u32("submit t_steps")?;
                let inputs = c.u32("submit inputs")?;
                let expect = packed_len(t_steps as u64 * inputs as u64);
                if c.remaining() as u64 != expect {
                    // Too few is truncation, too many is trailing garbage;
                    // either way the declared geometry and the payload
                    // disagree.
                    return Err(WireError::BadValue("spike payload arity"));
                }
                let spikes = c.take(expect as usize, "submit spikes")?.to_vec();
                Frame::SubmitSample { session, sample, t_steps, inputs, spikes }
            }
            6 => {
                let session = c.u32("reconfig session")?;
                let request = c.u64("reconfig request id")?;
                let n_cfg = c.u16("reconfig n_cfg")? as usize;
                let mut cfg = Vec::new();
                for _ in 0..n_cfg {
                    let addr = c.u16("reconfig cfg addr")?;
                    let value = c.i32("reconfig cfg value")?;
                    cfg.push((addr, value));
                }
                let n_swap = c.u16("reconfig n_swap")? as usize;
                let mut weights = Vec::new();
                for _ in 0..n_swap {
                    let layer = c.u16("reconfig swap layer")?;
                    let words = c.u32("reconfig swap words")? as usize;
                    // Validate the byte count *before* sizing a buffer from
                    // the attacker-controlled word count.
                    let raw = c.take(
                        words.checked_mul(4).ok_or(WireError::BadValue("swap word count"))?,
                        "reconfig swap payload",
                    )?;
                    let payload = raw
                        .chunks_exact(4)
                        .map(|s| i32::from_le_bytes([s[0], s[1], s[2], s[3]]))
                        .collect();
                    weights.push((layer, payload));
                }
                Frame::Reconfig { session, request, cfg, weights }
            }
            7 => {
                let session = c.u32("result session")?;
                let sample = c.u64("result sample id")?;
                let epoch = c.u64("result epoch")?;
                let prediction = c.u32("result prediction")?;
                let spikes_total = c.u64("result spikes_total")?;
                let n = c.u16("result n_counts")? as usize;
                let raw = c.take(
                    n.checked_mul(4).ok_or(WireError::BadValue("counts arity"))?,
                    "result counts",
                )?;
                let counts =
                    raw.chunks_exact(4).map(|s| u32::from_le_bytes([s[0], s[1], s[2], s[3]])).collect();
                Frame::Result { session, sample, epoch, prediction, spikes_total, counts }
            }
            8 => Frame::ReconfigAck {
                session: c.u32("ack session")?,
                request: c.u64("ack request id")?,
                epoch: c.u64("ack epoch")?,
            },
            9 => {
                let code = ErrorCode::from_u16(c.u16("error code")?)
                    .ok_or(WireError::BadValue("error code"))?;
                let session = c.u32("error session")?;
                let reference = c.u64("error reference")?;
                let n = c.u16("error msg_len")? as usize;
                let raw = c.take(n, "error message")?;
                let message = std::str::from_utf8(raw)
                    .map_err(|_| WireError::BadValue("error message utf-8"))?
                    .to_string();
                Frame::Error { code, session, reference, message }
            }
            10 => Frame::Snapshot {
                session: c.u32("snapshot session")?,
                request: c.u64("snapshot request id")?,
            },
            11 | 12 => {
                let session = c.u32("connectome session")?;
                let request = c.u64("connectome request id")?;
                let n = c.u32("connectome byte_len")? as usize;
                // Validate against the bytes actually present before any
                // allocation is sized from the declared length.
                let bytes = c.take(n, "connectome payload")?.to_vec();
                if t == 11 {
                    Frame::SnapshotData { session, request, bytes }
                } else {
                    Frame::Restore { session, request, bytes }
                }
            }
            13 => Frame::RestoreAck {
                session: c.u32("restore ack session")?,
                request: c.u64("restore ack request id")?,
                epoch: c.u64("restore ack epoch")?,
            },
            14 => Frame::HealthReq { request: c.u64("health request id")? },
            15 => {
                let request = c.u64("health request id")?;
                let degraded = match c.u8("health degraded flag")? {
                    0 => false,
                    1 => true,
                    _ => return Err(WireError::BadValue("health degraded flag")),
                };
                let recoveries = c.u64("health recoveries")?;
                let quarantines = c.u64("health quarantines")?;
                let checkpoint_age = c.u64("health checkpoint age")?;
                let scrubbed_blocks = c.u64("health scrubbed blocks")?;
                let corrected = c.u64("health corrected words")?;
                let detected = c.u64("health detected words")?;
                let n = c.u16("health n_shards")? as usize;
                let shards = c.take(n, "health shard statuses")?.to_vec();
                if shards.iter().any(|&s| s > 2) {
                    return Err(WireError::BadValue("health shard status"));
                }
                Frame::Health {
                    request,
                    degraded,
                    recoveries,
                    quarantines,
                    checkpoint_age,
                    scrubbed_blocks,
                    corrected,
                    detected,
                    shards,
                }
            }
            other => return Err(WireError::BadType(other)),
        };
        if c.remaining() != 0 {
            return Err(WireError::TrailingBytes { frame: frame.name(), extra: c.remaining() });
        }
        Ok(frame)
    }
}

/// Bytes needed to bit-pack `bits` spike lines.
fn packed_len(bits: u64) -> u64 {
    (bits + 7) / 8
}

/// Bit-pack a 0/1 byte vector LSB-first (the wire spike encoding).
pub fn pack_bits(bits: &[u8]) -> Vec<u8> {
    let mut out = vec![0u8; (bits.len() + 7) / 8];
    for (i, &b) in bits.iter().enumerate() {
        if b != 0 {
            out[i / 8] |= 1 << (i % 8);
        }
    }
    out
}

/// Expand `n` LSB-first packed bits back to a 0/1 byte vector.
pub fn unpack_bits(packed: &[u8], n: usize) -> Vec<u8> {
    (0..n).map(|i| (packed[i / 8] >> (i % 8)) & 1).collect()
}

/// Encode a [`Sample`] as a `SubmitSample` frame.
pub fn submit_from_sample(session: u32, sample_id: u64, s: &Sample) -> Frame {
    Frame::SubmitSample {
        session,
        sample: sample_id,
        t_steps: s.t_steps as u32,
        inputs: s.inputs as u32,
        spikes: pack_bits(&s.spikes),
    }
}

/// Reassemble the [`Sample`] carried by a `SubmitSample` frame (label 0 —
/// the wire carries stimuli, not supervision).
///
/// The `t_steps × inputs` bit count comes from attacker-controlled header
/// fields: it is computed with `checked_mul`, capped at the bits one
/// maximum-size frame could actually carry, and checked against the
/// payload arity — a hostile header is a typed [`WireError`], never an
/// overflow or an unbounded `unpack_bits` allocation.
pub fn sample_from_submit(t_steps: u32, inputs: u32, spikes: &[u8]) -> Result<Sample, WireError> {
    let n = (t_steps as usize)
        .checked_mul(inputs as usize)
        .filter(|&n| n <= DEFAULT_MAX_FRAME_LEN as usize * 8)
        .ok_or(WireError::BadValue("sample bit count overflows the frame cap"))?;
    if spikes.len() as u64 != packed_len(n as u64) {
        return Err(WireError::BadValue("spike payload arity"));
    }
    Ok(Sample {
        spikes: unpack_bits(spikes, n),
        t_steps: t_steps as usize,
        inputs: inputs as usize,
        label: 0,
    })
}

/// Convert a wire `Reconfig` frame into a control-plane program (the
/// validation against engine geometry happens in the control plane, not
/// here).
pub fn program_from_wire(cfg: &[(u16, i32)], weights: &[(u16, Vec<i32>)]) -> ReconfigProgram {
    let mut p = ReconfigProgram::new();
    for &(addr, value) in cfg {
        p = p.write(addr as usize, value);
    }
    for (layer, payload) in weights {
        p = p.swap_weights(*layer as usize, payload.clone());
    }
    p
}

/// Encode a control-plane program as a wire `Reconfig` frame. Fails with
/// [`WireError::BadValue`] if an address or layer index does not fit the
/// wire's `u16` fields (no real engine is near either bound).
pub fn program_to_wire(
    session: u32,
    request: u64,
    program: &ReconfigProgram,
) -> Result<Frame, WireError> {
    let mut cfg = Vec::with_capacity(program.cfg.len());
    for &(addr, value) in &program.cfg {
        if addr > u16::MAX as usize {
            return Err(WireError::BadValue("cfg address beyond u16"));
        }
        cfg.push((addr as u16, value));
    }
    let mut weights = Vec::with_capacity(program.weights.len());
    for (layer, payload) in &program.weights {
        if *layer > u16::MAX as usize {
            return Err(WireError::BadValue("layer index beyond u16"));
        }
        weights.push((*layer as u16, payload.clone()));
    }
    Ok(Frame::Reconfig { session, request, cfg, weights })
}

/// Write one length-prefixed frame. The caller flushes (batching several
/// frames per flush is the intended fast path).
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> Result<(), WireError> {
    let body = frame.encode()?;
    let len = u32::try_from(body.len()).map_err(|_| WireError::BadValue("frame too long"))?;
    w.write_all(&len.to_le_bytes())?;
    w.write_all(&body)?;
    Ok(())
}

/// Read one length-prefixed frame.
///
/// * `Ok(None)` — the peer closed the stream cleanly *between* frames.
/// * `Err(WireError::Idle)` — a read timeout fired between frames (the
///   socket has a timeout configured); poll your shutdown flag and retry.
/// * any other error — protocol violation or transport failure.
pub fn read_frame<R: Read>(r: &mut R, max_len: u32) -> Result<Option<Frame>, WireError> {
    let mut len_buf = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        match r.read(&mut len_buf[got..]) {
            Ok(0) => {
                return if got == 0 {
                    Ok(None)
                } else {
                    Err(WireError::Truncated { what: "length prefix" })
                };
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e)
                if got == 0
                    && matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) =>
            {
                return Err(WireError::Idle);
            }
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    let len = u32::from_le_bytes(len_buf);
    if len == 0 {
        return Err(WireError::Truncated { what: "empty frame body" });
    }
    if len > max_len {
        return Err(WireError::TooLarge { len, max: max_len });
    }
    let mut body = vec![0u8; len as usize];
    let mut filled = 0usize;
    while filled < body.len() {
        match r.read(&mut body[filled..]) {
            Ok(0) => return Err(WireError::Truncated { what: "frame body" }),
            Ok(n) => filled += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    Frame::decode(&body).map(Some)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_unpack_roundtrip() {
        let bits: Vec<u8> = (0..37).map(|i| (i % 3 == 0) as u8).collect();
        let packed = pack_bits(&bits);
        assert_eq!(packed.len(), 5);
        assert_eq!(unpack_bits(&packed, bits.len()), bits);
        assert!(pack_bits(&[]).is_empty());
        assert!(unpack_bits(&[], 0).is_empty());
    }

    #[test]
    fn frame_roundtrip_through_a_stream() {
        let frames = vec![
            Frame::Hello { version: VERSION },
            Frame::HelloAck { version: 1, inputs: 256, outputs: 10, cores: 2, lane_width: 64 },
            Frame::OpenSession { max_inflight: 0 },
            Frame::SessionOpened { session: 7, max_inflight: 64 },
            Frame::SubmitSample {
                session: 7,
                sample: 42,
                t_steps: 3,
                inputs: 5,
                spikes: pack_bits(&[1, 0, 1, 0, 0, 1, 1, 1, 0, 0, 0, 0, 1, 0, 1]),
            },
            Frame::Reconfig {
                session: 7,
                request: 9,
                cfg: vec![(2, 16), (0, -3)],
                weights: vec![(1, vec![1, -7, 0])],
            },
            Frame::Result {
                session: 7,
                sample: 42,
                epoch: 1,
                prediction: 3,
                spikes_total: 17,
                counts: vec![0, 1, 2, 9],
            },
            Frame::ReconfigAck { session: 7, request: 9, epoch: 1 },
            Frame::Error {
                code: ErrorCode::Overloaded,
                session: 7,
                reference: 43,
                message: "session quota full".into(),
            },
            Frame::Snapshot { session: 7, request: 11 },
            Frame::SnapshotData { session: 7, request: 11, bytes: vec![0xAB; 9] },
            Frame::Restore { session: 7, request: 12, bytes: vec![1, 2, 3, 4] },
            Frame::RestoreAck { session: 7, request: 12, epoch: 2 },
            Frame::Error {
                code: ErrorCode::ShardLost,
                session: 7,
                reference: 44,
                message: "serving shard 1 was lost mid-stream".into(),
            },
            Frame::HealthReq { request: 13 },
            Frame::Health {
                request: 13,
                degraded: true,
                recoveries: 3,
                quarantines: 4,
                checkpoint_age: 129,
                scrubbed_blocks: 65536,
                corrected: 2,
                detected: 1,
                shards: vec![0, 2, 0],
            },
        ];
        let mut buf = Vec::new();
        for f in &frames {
            write_frame(&mut buf, f).unwrap();
        }
        let mut r = io::Cursor::new(buf);
        for f in &frames {
            let got = read_frame(&mut r, DEFAULT_MAX_FRAME_LEN).unwrap().unwrap();
            assert_eq!(&got, f);
        }
        assert!(read_frame(&mut r, DEFAULT_MAX_FRAME_LEN).unwrap().is_none(), "clean EOF");
    }

    #[test]
    fn oversized_and_malformed_frames_are_typed_errors() {
        // Hostile length prefix: rejected before any allocation.
        let mut r = io::Cursor::new(u32::MAX.to_le_bytes().to_vec());
        assert!(matches!(read_frame(&mut r, 1024), Err(WireError::TooLarge { .. })));
        // Zero-length body.
        let mut r = io::Cursor::new(0u32.to_le_bytes().to_vec());
        assert!(matches!(read_frame(&mut r, 1024), Err(WireError::Truncated { .. })));
        // Unknown type byte.
        assert!(matches!(Frame::decode(&[0xEE]), Err(WireError::BadType(0xEE))));
        // Bad magic.
        let mut body = vec![1u8];
        body.extend_from_slice(&0xDEADBEEFu32.to_le_bytes());
        body.extend_from_slice(&VERSION.to_le_bytes());
        assert!(matches!(Frame::decode(&body), Err(WireError::BadMagic(0xDEADBEEF))));
        // Trailing bytes.
        let mut ok = Frame::OpenSession { max_inflight: 4 }.encode().unwrap();
        ok.push(0);
        assert!(matches!(Frame::decode(&ok), Err(WireError::TrailingBytes { .. })));
        // Spike arity mismatch.
        let bad = Frame::SubmitSample {
            session: 1,
            sample: 1,
            t_steps: 8,
            inputs: 8,
            spikes: vec![0; 3], // needs 8
        };
        assert!(matches!(bad.encode(), Err(WireError::BadValue(_))));
        // Hostile header: t_steps * inputs overflows the frame budget — typed
        // error, no panic, no attacker-sized allocation.
        assert!(matches!(
            sample_from_submit(u32::MAX, u32::MAX, &[]),
            Err(WireError::BadValue(_))
        ));
        // Plausible header whose product exceeds the frame budget.
        assert!(matches!(
            sample_from_submit(1 << 20, 1 << 20, &[]),
            Err(WireError::BadValue(_))
        ));
        // Health frame domain checks: a bad degraded flag or an unknown
        // shard status byte is a typed error, not a silent acceptance.
        let mut h = Frame::Health {
            request: 1,
            degraded: false,
            recoveries: 0,
            quarantines: 0,
            checkpoint_age: 0,
            scrubbed_blocks: 0,
            corrected: 0,
            detected: 0,
            shards: vec![0],
        }
        .encode()
        .unwrap();
        h[9] = 9; // degraded flag byte (type + request id precede it)
        assert!(matches!(Frame::decode(&h), Err(WireError::BadValue(_))));
        let mut h2 = Frame::Health {
            request: 1,
            degraded: true,
            recoveries: 0,
            quarantines: 0,
            checkpoint_age: 0,
            scrubbed_blocks: 0,
            corrected: 0,
            detected: 0,
            shards: vec![3],
        }
        .encode()
        .unwrap();
        assert!(matches!(Frame::decode(&h2), Err(WireError::BadValue(_))));
        h2.pop();
        assert!(matches!(Frame::decode(&h2), Err(WireError::Truncated { .. })));
    }

    #[test]
    fn program_conversion_roundtrip() {
        let p = ReconfigProgram::new().write(2, 16).swap_weights(1, vec![3, -3]);
        let f = program_to_wire(9, 1, &p).unwrap();
        match &f {
            Frame::Reconfig { cfg, weights, .. } => {
                assert_eq!(program_from_wire(cfg, weights), p);
            }
            _ => unreachable!(),
        }
    }
}
