//! Hardware-software interface — paper §IV / Fig. 7.
//!
//! The application software talks to the core through three interfaces
//! (§II): `wt_in` programs synaptic memory (per-weight addressing),
//! `cfg_in` programs the decoder's control registers, and `spk_in/out`
//! streams AER spikes. On the FPGA these ride the AXI interconnect between
//! the PS (MicroBlaze/ARM) and the PL; here the same transactions drive the
//! cycle-accurate [`crate::hdl::Core`], with a transaction ledger standing
//! in for the bus (transfer counts × beat size = modelled bus occupancy).

use anyhow::Result;

use crate::config::registers::ResetMode;
use crate::config::ModelConfig;
use crate::datasets::Sample;
use crate::hdl::aer::{self, AerEvent};
use crate::hdl::core::RunResult;
use crate::hdl::Core;

use super::control::{ControlError, ReconfigProgram};

/// AXI transaction ledger (one beat per word; the §IV bus model).
///
/// Both the single-core [`Device`] and the sharded
/// [`ServingEngine`](super::serving::ServingEngine) meter their traffic on
/// this ledger: cfg_in/wt_in control beats and spk_in/spk_out data beats,
/// one counter set, so reconfiguration cost is directly comparable to data
/// cost ([`BusStats::beats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BusStats {
    pub wt_writes: u64,
    pub cfg_writes: u64,
    pub spk_in_events: u64,
    pub spk_out_events: u64,
}

impl BusStats {
    /// Total bus beats (32-bit words moved).
    pub fn beats(&self) -> u64 {
        self.wt_writes + self.cfg_writes + self.spk_in_events + self.spk_out_events
    }
}

/// The deployed device: a QUANTISENC core behind its software interface.
pub struct Device {
    core: Core,
    bus: BusStats,
}

impl Device {
    pub fn new(config: ModelConfig) -> Device {
        Device { core: Core::new(config), bus: BusStats::default() }
    }

    pub fn core(&self) -> &Core {
        &self.core
    }

    pub fn core_mut(&mut self) -> &mut Core {
        &mut self.core
    }

    pub fn bus(&self) -> BusStats {
        self.bus
    }

    // --- wt_in --------------------------------------------------------------

    /// Program one synaptic weight (the paper's per-weight access granularity).
    pub fn write_weight(&mut self, layer: usize, pre: usize, post: usize, w: i32) -> Result<()> {
        let n_layers = self.core.config().num_layers();
        anyhow::ensure!(layer < n_layers, "layer address {layer} out of range ({n_layers} layers)");
        self.core.layer_mut(layer).memory_mut().write(pre, post, w)?;
        self.bus.wt_writes += 1;
        Ok(())
    }

    /// Bulk-program trained weights from an artifact (counts every word as
    /// a bus beat, like a DMA of the full weight file).
    pub fn load_weights(&mut self, per_layer: &[Vec<i32>]) -> Result<()> {
        self.core.load_weights(per_layer)?;
        self.bus.wt_writes += per_layer.iter().map(|w| w.len() as u64).sum::<u64>();
        Ok(())
    }

    // --- cfg_in -------------------------------------------------------------

    pub fn write_register(&mut self, addr: usize, value: i32) -> Result<()> {
        self.core.registers.write(addr, value)?;
        self.bus.cfg_writes += 1;
        Ok(())
    }

    /// Typed convenience: the application-software knobs of Table I.
    pub fn configure(
        &mut self,
        decay: f64,
        growth: f64,
        vth: f64,
        reset: ResetMode,
        refractory: i32,
    ) -> Result<()> {
        self.core.registers.set_decay(decay)?;
        self.core.registers.set_growth(growth)?;
        self.core.registers.set_vth(vth)?;
        self.core.registers.set_reset_mode(reset)?;
        self.core.registers.set_refractory(refractory)?;
        self.bus.cfg_writes += 5;
        Ok(())
    }

    /// Program the R/C operating point (Fig. 3 / Table X).
    pub fn set_rc(&mut self, r_mohm: f64, c_pf: f64) -> Result<()> {
        self.core.registers.set_rc(r_mohm, c_pf)?;
        self.bus.cfg_writes += 2;
        Ok(())
    }

    /// Apply a whole [`ReconfigProgram`] — the same cfg_in/wt_in unit the
    /// live serving engine's [`super::control::ControlPlane`] broadcasts —
    /// to this single deployed core. Rejection is all-or-nothing with a
    /// typed [`ControlError`]; an accepted program charges one cfg beat
    /// per register write and one wt beat per packed word, like the
    /// engine's per-shard accounting with C = 1.
    pub fn apply_program(&mut self, program: &ReconfigProgram) -> Result<(), ControlError> {
        // Validate wt_in payloads first (same shared check as the engine's
        // control plane) so the register commit never has to be rolled
        // back.
        let packed_sizes: Vec<usize> =
            self.core.layers().iter().map(|l| l.memory().synapses()).collect();
        program.validate_weights(self.core.config().qspec, &packed_sizes)?;
        self.core.registers.apply_program(&program.cfg)?;
        for (k, payload) in &program.weights {
            self.core
                .layer_mut(*k)
                .load_packed(payload)
                .expect("payload validated above");
        }
        self.bus.cfg_writes += program.cfg_beats();
        self.bus.wt_writes += program.wt_beats();
        Ok(())
    }

    // --- spk_in / spk_out ----------------------------------------------------

    /// Stream one sample as AER events and return the result + output
    /// events. Fully event-driven end-to-end: spk_in decodes straight into
    /// bit-packed planes, the core steps on planes, and spk_out events
    /// come off the output plane in the same single pass (the dense
    /// [T × N] buffer and the second deterministic re-run of the old
    /// implementation are both gone). Bit-identical to
    /// [`Core::run`] on the decoded sample.
    pub fn infer_aer(
        &mut self,
        events: &[AerEvent],
        t_steps: usize,
    ) -> Result<(RunResult, Vec<AerEvent>)> {
        let width = self.core.config().inputs();
        let planes = aer::decode_planes(events, t_steps, width)?;
        self.bus.spk_in_events += events.len() as u64;
        let mut out_events = Vec::new();
        let result = self.core.run_with(
            t_steps,
            |t, plane| plane.copy_from(&planes[t]),
            |t, out| aer::extend_from_plane(&mut out_events, t as u32, out),
        );
        self.bus.spk_out_events += out_events.len() as u64;
        Ok((result, out_events))
    }

    /// Dense-path inference (the common case behind the pipeline).
    pub fn infer_dense(&mut self, sample: &Sample) -> RunResult {
        self.bus.spk_in_events += sample.nnz() as u64;
        self.core.run(sample)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::Q5_3;

    fn device() -> Device {
        let cfg = ModelConfig::parse_arch("4x3x2", Q5_3).unwrap();
        let mut d = Device::new(cfg);
        for i in 0..4 {
            d.write_weight(0, i, 0, 8).unwrap();
        }
        d.write_weight(1, 0, 0, 16).unwrap();
        d
    }

    #[test]
    fn bus_ledger_counts_transactions() {
        let mut d = device();
        assert_eq!(d.bus().wt_writes, 5);
        d.write_register(2, 8).unwrap();
        assert_eq!(d.bus().cfg_writes, 1);
        d.configure(0.2, 1.0, 1.0, ResetMode::ToZero, 0).unwrap();
        assert_eq!(d.bus().cfg_writes, 6);
        assert_eq!(d.bus().beats(), 11);
    }

    #[test]
    fn bad_transactions_rejected_and_not_counted() {
        let mut d = device();
        let before = d.bus();
        assert!(d.write_weight(0, 9, 0, 1).is_err());
        assert!(d.write_register(99, 0).is_err());
        assert_eq!(d.bus(), before);
    }

    #[test]
    fn aer_roundtrip_inference() {
        let mut d = device();
        let events: Vec<AerEvent> = (0..5)
            .flat_map(|t| (0..4).map(move |a| AerEvent { t, addr: a }))
            .collect();
        let (result, out_events) = d.infer_aer(&events, 5).unwrap();
        assert!(result.counts[0] > 0);
        assert_eq!(out_events.iter().map(|_| 1u32).sum::<u32>() as u32, result.counts.iter().sum::<u32>());
        assert_eq!(d.bus().spk_in_events, 20);
    }

    #[test]
    fn apply_program_is_atomic_and_metered() {
        let mut d = device();
        let beats_before = d.bus().beats();
        // cfg write + a full wt_in swap of layer 1 (3x2 all-to-all = 6 words).
        let prog = ReconfigProgram::new()
            .write(crate::config::registers::REG_VTH, 24)
            .swap_weights(1, vec![5; 6]);
        d.apply_program(&prog).unwrap();
        assert_eq!(d.core().registers.vth(), 24);
        assert_eq!(d.core().layers()[1].memory().read(2, 1).unwrap(), 5);
        assert_eq!(d.bus().beats(), beats_before + 1 + 6);
        // A program with any invalid part must change nothing.
        let before = (d.bus(), d.core().registers.clone());
        let bad = ReconfigProgram::new()
            .write(crate::config::registers::REG_VTH, 8)
            .swap_weights(9, vec![0; 6]);
        assert_eq!(
            d.apply_program(&bad),
            Err(ControlError::BadLayer { layer: 9, layers: 2 })
        );
        assert_eq!(d.bus(), before.0);
        assert_eq!(d.core().registers, before.1);
        assert!(matches!(
            d.apply_program(&ReconfigProgram::new().swap_weights(1, vec![0; 2])),
            Err(ControlError::PayloadSize { layer: 1, expect: 6, got: 2 })
        ));
    }

    #[test]
    fn dynamic_reconfiguration_changes_behaviour() {
        let mut d = device();
        let sample = Sample { spikes: vec![1, 1, 1, 1].repeat(6), t_steps: 6, inputs: 4, label: 0 };
        let base = d.infer_dense(&sample);
        // Raise the threshold far above reach: the core must go silent.
        d.write_register(crate::config::registers::REG_VTH, Q5_3.from_float(15.0)).unwrap();
        let quiet = d.infer_dense(&sample);
        assert!(quiet.stats.spikes < base.stats.spikes);
        assert_eq!(quiet.stats.spikes, 0);
    }
}
