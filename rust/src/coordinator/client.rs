//! Client side of the network front door: [`WireClient`] (a thin typed
//! handle over the [`super::wire`] frame protocol) and the open-loop load
//! generator behind `repro loadgen`.
//!
//! The load generator measures the server the way the paper's evaluation
//! measures the core — offered load in, latency/throughput out — but at
//! the serving boundary: Poisson (optionally bursty) arrivals per
//! session, client-clocked request latency, typed `Overloaded` rejections
//! counted against offered load, and (when the caller supplies an oracle)
//! bit-exact verification of every spike count that comes back.

use std::collections::HashMap;
use std::io::{BufReader, BufWriter, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::datasets::rng::XorShift64Star;
use crate::datasets::{Dataset, Sample, Split};
use crate::hdl::ActivityStats;

use super::control::ReconfigProgram;
use super::metrics::Telemetry;
use super::wire::{self, ErrorCode, Frame, WireError};

/// Engine geometry reported by the server's `HelloAck`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HelloInfo {
    pub inputs: u32,
    pub outputs: u32,
    pub cores: u16,
    pub lane_width: u16,
}

/// Client-side retry/backoff policy for idempotent requests.
///
/// Submits are pure functions of the sample (the engine holds no
/// per-stream state across samples), so resubmitting after a typed
/// `ShardLost` or `Overloaded` rejection is always safe — the retried
/// result is bit-identical to what the lost one would have been. Backoff
/// is capped exponential with **deterministic jitter**: the sleep before
/// attempt `k` of request `r` is a pure function of `(seed, r, k)`, so a
/// chaos soak replays byte-identically from its command line while
/// distinct requests still decorrelate (no thundering herd on recovery).
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Total attempts including the first (1 = no retries).
    pub max_attempts: u32,
    /// Backoff before the first retry; doubles per attempt.
    pub base: Duration,
    /// Upper bound on any single backoff sleep.
    pub cap: Duration,
    /// Per-request wall-clock budget: a retry whose backoff would land
    /// past this deadline fails with a typed error instead of sleeping.
    pub deadline: Duration,
    /// Jitter seed (vary per client to decorrelate whole processes).
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 4,
            base: Duration::from_millis(5),
            cap: Duration::from_millis(200),
            deadline: Duration::from_secs(2),
            seed: 0xB0FF,
        }
    }
}

impl RetryPolicy {
    /// The backoff sleep before retry `attempt` (1-based: the sleep after
    /// the first failure is `backoff(r, 1)`). Capped exponential —
    /// `base · 2^(attempt-1)`, clamped to `cap` — scaled by a
    /// deterministic jitter factor in `[0.5, 1.0)` drawn from
    /// `(seed, request, attempt)`.
    pub fn backoff(&self, request: u64, attempt: u32) -> Duration {
        let exp = self.base.as_secs_f64() * 2f64.powi(attempt.saturating_sub(1).min(32) as i32);
        let capped = exp.min(self.cap.as_secs_f64());
        let mut rng = XorShift64Star::new(
            self.seed
                ^ request.wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ (attempt as u64).wrapping_mul(0xD1B5_4A32_D192_ED03),
        );
        let jitter = 0.5 + 0.5 * rng.uniform();
        Duration::from_secs_f64(capped * jitter)
    }
}

/// What [`WireClient::submit_with_retry`] returns: the (bit-exact) result
/// plus the retry telemetry the chaos soak aggregates.
#[derive(Debug, Clone)]
pub struct RetryOutcome {
    pub epoch: u64,
    pub prediction: u32,
    pub spikes_total: u64,
    pub counts: Vec<u32>,
    /// Attempts spent, including the successful one (1 = first try).
    pub attempts: u32,
    /// Typed `ShardLost` rejections absorbed along the way.
    pub shard_losses: u32,
    /// Typed `Overloaded` rejections absorbed along the way.
    pub overloads: u32,
    /// Fresh connections dialed after idle expiries (`IdleTimeout` frames
    /// or sockets the server had already closed).
    pub reconnects: u32,
}

/// Supervision state reported by a wire `Health` frame (see
/// [`WireClient::health`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HealthInfo {
    /// True while any shard is not `Healthy`.
    pub degraded: bool,
    pub recoveries: u64,
    pub quarantines: u64,
    /// Samples completed since the engine's live recovery point.
    pub checkpoint_age: u64,
    /// Parity/SECDED blocks swept by the engine's background scrubber.
    pub scrubbed_blocks: u64,
    /// Single-bit upsets repaired in place by SECDED.
    pub corrected: u64,
    /// Detected-uncorrectable words (each one a quarantine cause).
    pub detected: u64,
    /// One status byte per shard: 0 Healthy, 1 Quarantined, 2 Rebuilding.
    pub shards: Vec<u8>,
}

/// Write half of a connection (own thread-safe handle after
/// [`WireClient::into_split`]).
pub struct ClientSender {
    writer: BufWriter<TcpStream>,
}

impl ClientSender {
    /// Send one frame and flush it onto the socket.
    pub fn send(&mut self, frame: &Frame) -> Result<(), WireError> {
        wire::write_frame(&mut self.writer, frame)?;
        self.writer.flush()?;
        Ok(())
    }

    pub fn submit(&mut self, session: u32, sample_id: u64, s: &Sample) -> Result<(), WireError> {
        self.send(&wire::submit_from_sample(session, sample_id, s))
    }

    pub fn reconfig(
        &mut self,
        session: u32,
        request: u64,
        program: &ReconfigProgram,
    ) -> Result<(), WireError> {
        let frame = wire::program_to_wire(session, request, program)?;
        self.send(&frame)
    }

    /// Ask the server for a connectome snapshot of its engine; the reply
    /// arrives as a `SnapshotData` frame.
    pub fn snapshot(&mut self, session: u32, request: u64) -> Result<(), WireError> {
        self.send(&Frame::Snapshot { session, request })
    }

    /// Offer an encoded connectome for live migration; the reply arrives
    /// as a `RestoreAck` frame carrying the assigned config epoch.
    pub fn restore(&mut self, session: u32, request: u64, bytes: Vec<u8>) -> Result<(), WireError> {
        self.send(&Frame::Restore { session, request, bytes })
    }
}

/// Read half of a connection.
pub struct ClientReceiver {
    reader: BufReader<TcpStream>,
    max_frame_len: u32,
}

impl ClientReceiver {
    /// Configure a socket read timeout; with one set,
    /// [`ClientReceiver::next_frame`] returns [`WireError::Idle`] when it
    /// fires between frames.
    pub fn set_read_timeout(&self, timeout: Option<Duration>) -> std::io::Result<()> {
        self.reader.get_ref().set_read_timeout(timeout)
    }

    /// Read one frame; `Ok(None)` is a clean server-side close.
    pub fn next_frame(&mut self) -> Result<Option<Frame>, WireError> {
        wire::read_frame(&mut self.reader, self.max_frame_len)
    }
}

/// A connected, handshaken client. Blocking and single-threaded; call
/// [`WireClient::into_split`] to drive sends and receives from separate
/// threads (the load generator's open-loop mode).
pub struct WireClient {
    sender: ClientSender,
    receiver: ClientReceiver,
    pub hello: HelloInfo,
    /// Address the connection was dialed to — kept so retry paths can
    /// dial a fresh connection after the server expires this one.
    addr: String,
}

impl WireClient {
    /// Connect and perform the `Hello`/`HelloAck` handshake.
    pub fn connect(addr: &str) -> Result<WireClient> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_nodelay(true)?;
        let reader = BufReader::new(stream.try_clone()?);
        let mut client = WireClient {
            sender: ClientSender { writer: BufWriter::new(stream) },
            receiver: ClientReceiver { reader, max_frame_len: wire::DEFAULT_MAX_FRAME_LEN },
            hello: HelloInfo { inputs: 0, outputs: 0, cores: 0, lane_width: 0 },
            addr: addr.to_string(),
        };
        client.send(&Frame::Hello { version: wire::VERSION })?;
        match client.recv()? {
            Frame::HelloAck { version: _, inputs, outputs, cores, lane_width } => {
                client.hello = HelloInfo { inputs, outputs, cores, lane_width };
            }
            other => bail!("expected HelloAck, got {other:?}"),
        }
        Ok(client)
    }

    /// Replace this handle's transport with a freshly dialed, handshaken
    /// connection to the same address (used after the server expires the
    /// old one for idleness). Sessions do not survive the old connection —
    /// callers must open a replacement on the new one.
    pub fn reconnect(&mut self) -> Result<()> {
        let fresh = WireClient::connect(&self.addr)?;
        self.sender = fresh.sender;
        self.receiver = fresh.receiver;
        self.hello = fresh.hello;
        Ok(())
    }

    pub fn send(&mut self, frame: &Frame) -> Result<(), WireError> {
        self.sender.send(frame)
    }

    /// Block until the next frame arrives (treats a server close as an
    /// error — the serving protocol never half-closes mid-conversation).
    pub fn recv(&mut self) -> Result<Frame> {
        loop {
            match self.receiver.next_frame() {
                Ok(Some(f)) => return Ok(f),
                Ok(None) => bail!("server closed the connection"),
                Err(WireError::Idle) => continue,
                Err(e) => return Err(e.into()),
            }
        }
    }

    /// Open a session; returns `(session id, granted in-flight quota)`.
    /// `max_inflight == 0` asks for the server default.
    pub fn open_session(&mut self, max_inflight: u32) -> Result<(u32, u32)> {
        self.send(&Frame::OpenSession { max_inflight })?;
        match self.recv()? {
            Frame::SessionOpened { session, max_inflight } => Ok((session, max_inflight)),
            Frame::Error { code, message, .. } => {
                bail!("server refused session ({code:?}): {message}")
            }
            other => bail!("expected SessionOpened, got {other:?}"),
        }
    }

    pub fn submit(&mut self, session: u32, sample_id: u64, s: &Sample) -> Result<(), WireError> {
        self.sender.submit(session, sample_id, s)
    }

    pub fn reconfig(
        &mut self,
        session: u32,
        request: u64,
        program: &ReconfigProgram,
    ) -> Result<(), WireError> {
        self.sender.reconfig(session, request, program)
    }

    /// Fetch the engine's connectome over the wire: sends `Snapshot` and
    /// blocks for the matching `SnapshotData`, returning the encoded bytes
    /// (decode with
    /// [`Connectome::decode`](super::connectome::Connectome::decode)).
    pub fn snapshot(&mut self, session: u32, request: u64) -> Result<Vec<u8>> {
        self.sender.snapshot(session, request)?;
        match self.recv()? {
            Frame::SnapshotData { request: r, bytes, .. } if r == request => Ok(bytes),
            Frame::Error { code, message, .. } => {
                bail!("server refused snapshot ({code:?}): {message}")
            }
            other => bail!("expected SnapshotData, got {other:?}"),
        }
    }

    /// Live blue/green migration: sends an encoded connectome as a
    /// `Restore` frame and blocks for the `RestoreAck`, returning the one
    /// config epoch the swap was assigned.
    pub fn restore(&mut self, session: u32, request: u64, bytes: Vec<u8>) -> Result<u64> {
        self.sender.restore(session, request, bytes)?;
        match self.recv()? {
            Frame::RestoreAck { request: r, epoch, .. } if r == request => Ok(epoch),
            Frame::Error { code, message, .. } => {
                bail!("server refused restore ({code:?}): {message}")
            }
            other => bail!("expected RestoreAck, got {other:?}"),
        }
    }

    /// Poll the server's supervision state ([`Frame::HealthReq`] →
    /// [`Frame::Health`]); answered from the pump's telemetry mirror, so
    /// it works even while the engine is mid-recovery.
    pub fn health(&mut self, request: u64) -> Result<HealthInfo> {
        self.send(&Frame::HealthReq { request })?;
        match self.recv()? {
            Frame::Health {
                request: r,
                degraded,
                recoveries,
                quarantines,
                checkpoint_age,
                scrubbed_blocks,
                corrected,
                detected,
                shards,
            } if r == request => Ok(HealthInfo {
                degraded,
                recoveries,
                quarantines,
                checkpoint_age,
                scrubbed_blocks,
                corrected,
                detected,
                shards,
            }),
            Frame::Error { code, message, .. } => {
                bail!("server refused health probe ({code:?}): {message}")
            }
            other => bail!("expected Health, got {other:?}"),
        }
    }

    /// Submit one sample and block for its result, absorbing retryable
    /// rejections under `policy`. Retries fire on typed `ShardLost` (the
    /// stream was on a shard that died; the supervisor is rebuilding it)
    /// and `Overloaded` (admission backpressure) — both idempotent-safe —
    /// and sleep `policy.backoff(...)` between attempts. An idle-expired
    /// connection (a typed `IdleTimeout` frame, or a send/receive failing
    /// because the server already closed the socket) is also retryable,
    /// but on a *fresh* connection: the client redials, opens a
    /// replacement session, and resubmits there. Every other error code,
    /// retry-budget exhaustion, and deadline overrun are typed failures.
    pub fn submit_with_retry(
        &mut self,
        session: u32,
        sample_id: u64,
        s: &Sample,
        policy: &RetryPolicy,
    ) -> Result<RetryOutcome> {
        let start = Instant::now();
        let budget = policy.max_attempts.max(1);
        let mut session = session;
        let mut shard_losses = 0u32;
        let mut overloads = 0u32;
        let mut reconnects = 0u32;
        for attempt in 1..=budget {
            let reply = match self.submit(session, sample_id, s) {
                Ok(()) => self.recv(),
                Err(e) => Err(e.into()),
            };
            let frame = match reply {
                Ok(f) => f,
                Err(e) => {
                    // The socket died under us — the server closes
                    // idle-expired connections right after its courtesy
                    // error frame, so the write or read can fail before
                    // that frame is ever seen. Submits are idempotent, so
                    // a fresh connection and session make this retryable.
                    if attempt == budget {
                        return Err(e.context(format!(
                            "submit {sample_id}: connection lost after {attempt} attempts"
                        )));
                    }
                    session = self.reopen(policy, sample_id, attempt, start)?;
                    reconnects += 1;
                    continue;
                }
            };
            match frame {
                Frame::Result { sample, epoch, prediction, spikes_total, counts, .. }
                    if sample == sample_id =>
                {
                    return Ok(RetryOutcome {
                        epoch,
                        prediction,
                        spikes_total,
                        counts,
                        attempts: attempt,
                        shard_losses,
                        overloads,
                        reconnects,
                    });
                }
                Frame::Error { code: ErrorCode::IdleTimeout, message, .. } => {
                    // The idle kill is addressed to the connection, not to
                    // any request (reference 0), and the server closes the
                    // socket right behind it — the old session is gone.
                    if attempt == budget {
                        bail!(
                            "submit {sample_id} failed (IdleTimeout) after {attempt} attempts: \
                             {message}"
                        );
                    }
                    session = self.reopen(policy, sample_id, attempt, start)?;
                    reconnects += 1;
                }
                Frame::Error { code, reference, message, .. } if reference == sample_id => {
                    match code {
                        ErrorCode::ShardLost => shard_losses += 1,
                        ErrorCode::Overloaded => overloads += 1,
                        _ => bail!("submit {sample_id} rejected ({code:?}): {message}"),
                    }
                    if attempt == budget {
                        bail!(
                            "submit {sample_id} failed ({code:?}) after {attempt} attempts: \
                             {message}"
                        );
                    }
                    let nap = policy.backoff(sample_id, attempt);
                    if start.elapsed() + nap > policy.deadline {
                        bail!(
                            "submit {sample_id} deadline {:?} exhausted after {attempt} attempts \
                             (last error {code:?}: {message})",
                            policy.deadline
                        );
                    }
                    std::thread::sleep(nap);
                }
                other => bail!("unexpected frame while awaiting sample {sample_id}: {other:?}"),
            }
        }
        bail!("submit {sample_id}: retry budget exhausted")
    }

    /// Back off, dial a fresh connection, and open a replacement session
    /// after an idle expiry (see [`WireClient::submit_with_retry`]).
    fn reopen(
        &mut self,
        policy: &RetryPolicy,
        sample_id: u64,
        attempt: u32,
        start: Instant,
    ) -> Result<u32> {
        let nap = policy.backoff(sample_id, attempt);
        if start.elapsed() + nap > policy.deadline {
            bail!(
                "submit {sample_id} deadline {:?} exhausted while redialing after an idle expiry",
                policy.deadline
            );
        }
        std::thread::sleep(nap);
        self.reconnect()?;
        let (session, _) = self.open_session(0)?;
        Ok(session)
    }

    /// Split into independently-owned halves for concurrent send/receive.
    pub fn into_split(self) -> (ClientSender, ClientReceiver) {
        (self.sender, self.receiver)
    }
}

/// Open-loop load profile. Arrivals are Poisson at `rate_hz` per session
/// (optionally clustered into back-to-back bursts of `burst_len`, with
/// inter-burst gaps stretched to preserve the mean rate); `rate_hz == 0`
/// submits as fast as the socket accepts.
#[derive(Debug, Clone, Copy)]
pub struct LoadgenOptions {
    pub sessions: usize,
    pub samples_per_session: u64,
    pub rate_hz: f64,
    pub burst_len: u64,
    /// Send an (empty, count-preserving) `Reconfig` after every k-th
    /// sample; 0 disables. Exercises the in-band control path under load.
    pub reconfig_every: u64,
    pub dataset: Dataset,
    pub t_steps: usize,
    /// Distinct samples cycled through per session (sample id i maps to
    /// pool index `i % pool`).
    pub pool: usize,
    pub max_inflight: u32,
    pub seed: u64,
}

impl Default for LoadgenOptions {
    fn default() -> LoadgenOptions {
        LoadgenOptions {
            sessions: 2,
            samples_per_session: 64,
            rate_hz: 500.0,
            burst_len: 1,
            reconfig_every: 0,
            dataset: Dataset::Smnist,
            t_steps: 6,
            pool: 16,
            max_inflight: 32,
            seed: 0x10AD,
        }
    }
}

/// Aggregated load-generator outcome — the numbers behind
/// `BENCH_serving_slo.json`.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub sessions: usize,
    pub submitted: u64,
    pub results_ok: u64,
    pub reconfig_acks: u64,
    pub rejects: u64,
    /// Non-overload error frames received (protocol-level trouble).
    pub errors: u64,
    /// Results whose spike counts diverged from the caller's oracle.
    pub result_mismatches: u64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub mean_us: f64,
    pub samples_per_sec: f64,
    pub reject_rate: f64,
    pub elapsed_s: f64,
    /// True when an oracle was supplied and every result was checked.
    pub verified: bool,
}

/// The deterministic sample set both the load generator and any oracle
/// must share: pool index `i` is `dataset.sample(i, Test, t_steps)`.
pub fn sample_pool(dataset: Dataset, pool: usize, t_steps: usize) -> Vec<Sample> {
    (0..pool as u64).map(|i| dataset.sample(i, Split::Test, t_steps)).collect()
}

/// Exponential inter-arrival gap (seconds) for a Poisson process at
/// `rate` Hz, from one uniform draw in [0, 1).
fn exp_gap(u: f64, rate: f64) -> f64 {
    -(1.0 - u).ln() / rate
}

struct SessionOutcome {
    latencies_us: Vec<f64>,
    submitted: u64,
    results_ok: u64,
    reconfig_acks: u64,
    rejects: u64,
    errors: u64,
    result_mismatches: u64,
}

/// Reconfig request ids live in their own keyspace so they can never
/// collide with sample ids in the pending-latency map.
const RECONFIG_ID_BASE: u64 = 1 << 63;

/// Run the open-loop load generator against a front door at `addr`.
///
/// `oracle`, when given, holds the expected spike counts per pool index
/// (loadgen reconfigs are empty programs, so counts are epoch-invariant);
/// every `Result` frame is then verified bit-exactly against it.
pub fn run_loadgen(
    addr: &str,
    opts: &LoadgenOptions,
    oracle: Option<&[Vec<u32>]>,
) -> Result<LoadReport> {
    anyhow::ensure!(opts.sessions >= 1, "need at least one session");
    anyhow::ensure!(opts.pool >= 1, "need at least one pooled sample");
    anyhow::ensure!(opts.burst_len >= 1, "burst_len must be positive");
    if let Some(o) = oracle {
        anyhow::ensure!(o.len() == opts.pool, "oracle must cover the sample pool");
    }
    let pool = sample_pool(opts.dataset, opts.pool, opts.t_steps);
    let mut tel = Telemetry::new();
    tel.start();
    let outcomes: Vec<Result<SessionOutcome>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..opts.sessions)
            .map(|s| {
                let pool = &pool;
                scope.spawn(move || run_session_worker(addr, opts, s as u64, pool, oracle))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("loadgen worker panicked")).collect()
    });
    tel.stop();
    let mut report = LoadReport {
        sessions: opts.sessions,
        submitted: 0,
        results_ok: 0,
        reconfig_acks: 0,
        rejects: 0,
        errors: 0,
        result_mismatches: 0,
        p50_us: 0.0,
        p99_us: 0.0,
        mean_us: 0.0,
        samples_per_sec: 0.0,
        reject_rate: 0.0,
        elapsed_s: 0.0,
        verified: oracle.is_some(),
    };
    for outcome in outcomes {
        let o = outcome?;
        report.submitted += o.submitted;
        report.results_ok += o.results_ok;
        report.reconfig_acks += o.reconfig_acks;
        report.rejects += o.rejects;
        report.errors += o.errors;
        report.result_mismatches += o.result_mismatches;
        for us in o.latencies_us {
            tel.record(Duration::from_secs_f64(us / 1e6), &ActivityStats::default(), None);
        }
        for _ in 0..o.rejects {
            tel.record_reject();
        }
    }
    report.p50_us = tel.latency_us(50.0);
    report.p99_us = tel.latency_us(99.0);
    report.mean_us = tel.mean_latency_us();
    report.samples_per_sec = tel.throughput_rps();
    report.reject_rate = tel.reject_rate();
    report.elapsed_s = report.results_ok as f64
        / if report.samples_per_sec > 0.0 { report.samples_per_sec } else { f64::INFINITY };
    Ok(report)
}

fn run_session_worker(
    addr: &str,
    opts: &LoadgenOptions,
    session_idx: u64,
    pool: &[Sample],
    oracle: Option<&[Vec<u32>]>,
) -> Result<SessionOutcome> {
    let client = WireClient::connect(addr)?;
    anyhow::ensure!(
        client.hello.inputs as usize == pool[0].inputs,
        "engine expects {} inputs, pool samples have {}",
        client.hello.inputs,
        pool[0].inputs
    );
    let mut client = client;
    let (session, _granted) = client.open_session(opts.max_inflight)?;
    let (mut tx, rx) = client.into_split();
    rx.set_read_timeout(Some(Duration::from_secs(1)))?;
    let mut rx = rx;

    let n = opts.samples_per_session;
    let n_reconfigs = if opts.reconfig_every > 0 { n / opts.reconfig_every } else { 0 };
    let expected_replies = n + n_reconfigs;
    let pending: Mutex<HashMap<u64, Instant>> = Mutex::new(HashMap::new());
    let sender_done = AtomicBool::new(false);

    std::thread::scope(|scope| {
        let receiver = scope.spawn(|| -> Result<SessionOutcome> {
            let mut out = SessionOutcome {
                latencies_us: Vec::new(),
                submitted: 0,
                results_ok: 0,
                reconfig_acks: 0,
                rejects: 0,
                errors: 0,
                result_mismatches: 0,
            };
            let mut seen = 0u64;
            let mut idle_strikes = 0u32;
            while seen < expected_replies {
                let frame = match rx.next_frame() {
                    Ok(Some(f)) => f,
                    Ok(None) => bail!("server closed mid-session after {seen} replies"),
                    Err(WireError::Idle) => {
                        idle_strikes += 1;
                        // Give the server a long leash while the sender is
                        // still pacing itself, a short one once everything
                        // has been submitted.
                        let limit = if sender_done.load(Ordering::Acquire) { 30 } else { 600 };
                        if idle_strikes > limit {
                            bail!("timed out waiting for replies ({seen}/{expected_replies})");
                        }
                        continue;
                    }
                    Err(e) => return Err(e.into()),
                };
                idle_strikes = 0;
                seen += 1;
                match frame {
                    Frame::Result { sample, counts, .. } => {
                        if let Some(t0) = pending.lock().unwrap().remove(&sample) {
                            out.latencies_us.push(t0.elapsed().as_secs_f64() * 1e6);
                        }
                        if let Some(expected) = oracle {
                            let idx = (sample % pool.len() as u64) as usize;
                            if counts != expected[idx] {
                                out.result_mismatches += 1;
                            }
                        }
                        out.results_ok += 1;
                    }
                    Frame::ReconfigAck { .. } => out.reconfig_acks += 1,
                    Frame::Error { code: ErrorCode::Overloaded, reference, .. } => {
                        pending.lock().unwrap().remove(&reference);
                        out.rejects += 1;
                    }
                    Frame::Error { reference, .. } => {
                        pending.lock().unwrap().remove(&reference);
                        out.errors += 1;
                    }
                    other => bail!("unexpected frame mid-session: {other:?}"),
                }
            }
            Ok(out)
        });

        let sent: Result<u64> = (|| {
            let mut rng = XorShift64Star::new(
                opts.seed ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(session_idx + 1),
            );
            let start = Instant::now();
            let mut next_at = 0.0f64;
            let mut reconfigs_sent = 0u64;
            for i in 0..n {
                if opts.rate_hz > 0.0 && i % opts.burst_len == 0 {
                    // One exponential gap per burst, at rate/burst_len, so
                    // the long-run sample rate stays rate_hz.
                    next_at += exp_gap(rng.uniform(), opts.rate_hz / opts.burst_len as f64);
                    let target = Duration::from_secs_f64(next_at);
                    let elapsed = start.elapsed();
                    if target > elapsed {
                        std::thread::sleep(target - elapsed);
                    }
                }
                let sample = &pool[(i % pool.len() as u64) as usize];
                // Insert before send: the reply can beat a post-send insert.
                pending.lock().unwrap().insert(i, Instant::now());
                tx.submit(session, i, sample)?;
                if opts.reconfig_every > 0 && (i + 1) % opts.reconfig_every == 0 {
                    reconfigs_sent += 1;
                    tx.reconfig(session, RECONFIG_ID_BASE | reconfigs_sent, &ReconfigProgram::new())?;
                }
            }
            Ok(n)
        })();
        sender_done.store(true, Ordering::Release);

        let mut outcome = receiver.join().expect("loadgen receiver panicked")?;
        outcome.submitted = sent?;
        Ok(outcome)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exp_gap_matches_rate() {
        // Mean of many exponential draws at 100 Hz ≈ 10 ms.
        let mut rng = XorShift64Star::new(42);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| exp_gap(rng.uniform(), 100.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.01).abs() < 0.001, "mean gap {mean}");
    }

    #[test]
    fn sample_pool_is_deterministic() {
        let a = sample_pool(Dataset::Smnist, 4, 6);
        let b = sample_pool(Dataset::Smnist, 4, 6);
        assert_eq!(a.len(), 4);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.spikes, y.spikes, "pool must be reproducible for oracle checks");
        }
    }

    #[test]
    fn backoff_is_deterministic_capped_and_jittered() {
        let p = RetryPolicy::default();
        // Pure function of (seed, request, attempt): replayable soaks.
        assert_eq!(p.backoff(7, 1), p.backoff(7, 1));
        assert_eq!(p.backoff(7, 3), p.backoff(7, 3));
        // Distinct requests and attempts decorrelate.
        assert_ne!(p.backoff(7, 1), p.backoff(8, 1));
        assert_ne!(p.backoff(7, 1), p.backoff(7, 2));
        // Every sleep lands in [base·2^(k-1)/2, base·2^(k-1)) pre-cap...
        for attempt in 1..=3u32 {
            let nominal = p.base.as_secs_f64() * 2f64.powi(attempt as i32 - 1);
            for request in 0..50u64 {
                let b = p.backoff(request, attempt).as_secs_f64();
                assert!(b >= nominal * 0.5 - 1e-12, "attempt {attempt} req {request}: {b}");
                assert!(b < nominal, "attempt {attempt} req {request}: {b}");
            }
        }
        // ...and the cap bounds deep retries (attempt 40 would otherwise
        // be base·2^39 ≈ 32 days).
        assert!(p.backoff(1, 40) <= p.cap);
        // Different seeds give different jitter streams.
        let q = RetryPolicy { seed: 0xFEED, ..p };
        assert_ne!(p.backoff(7, 1), q.backoff(7, 1));
    }
}
