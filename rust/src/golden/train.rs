//! Native model synthesis — the request path's stand-in for the Python/JAX
//! QAT training loop (DESIGN.md substitution policy: the deployment stack
//! must be able to rebuild every artifact without Python).
//!
//! Each dataset gets a two-layer SNN whose weights are *calibrated*, not
//! gradient-trained:
//!
//! * **smnist** — the hidden layer is a bank of shift×thickness matched
//!   filters derived from the glyph generator's seven-segment geometry
//!   (6 strong "anchor" weights on the most class-distinctive cells plus
//!   strong negatives on rival-distinctive cells, one neuron per
//!   (class, jitter bin)); the output layer is a ridge-regression readout
//!   fitted on hidden spike counts over generated training samples, then
//!   projected onto a fixed-point-friendly tier structure.
//! * **dvs** — hidden matched filters estimated from class-mean spike-rate
//!   prototypes, with a hand-structured primary/secondary pooling readout.
//! * **shd** — prototype matched filters plus the ridge readout.
//!
//! The tier structure is what makes the quantization ladder behave like the
//! paper's Table VIII: anchor weights survive Q3.1's coarse grid, fine
//! weights survive Q5.3, and the continuous values only exist at Q9.7 and
//! up — while per-neuron positive/negative mass caps keep worst-case
//! activations inside even Q3.1's wrap range.

use crate::datasets::rng::XorShift64Star;
use crate::datasets::{smnist, Dataset, Split};

/// Timesteps used for calibration and recorded in the manifest.
pub const T_STEPS: usize = 30;

/// Weight tiers (value units). See module docs for how these interact with
/// the Qn.q grids.
const ANCHOR_W: f64 = 0.38;
const SMNIST_ANCHOR_NEG_W: f64 = 0.45;
const PROTO_ANCHOR_NEG_W: f64 = 0.33;
const FINE_CAP: f64 = 0.22;

/// One calibrated model (float weights; quantization happens per variant).
pub struct TrainedModel {
    pub dataset: Dataset,
    /// Layer sizes including the input layer, e.g. [256, 300, 10].
    pub sizes: Vec<usize>,
    pub t_steps: usize,
    /// Deployment threshold voltage (value units) written to default_regs.
    pub vth: f64,
    /// Per-layer dense row-major float weights ([fan_in × neurons]).
    pub weights: Vec<Vec<f64>>,
    /// Float ("software") accuracy of the calibrated model on the test split.
    pub float_acc: f64,
}

/// Per-dataset deployment threshold.
pub fn deploy_vth(ds: Dataset) -> f64 {
    match ds {
        Dataset::Smnist => 1.5,
        Dataset::Dvs => 1.0,
        Dataset::Shd => 1.5,
    }
}

fn neuron_rng(j: usize, seed_offset: u64) -> XorShift64Star {
    XorShift64Star::new(
        0x7EA1_0000u64
            .wrapping_add((j as u64).wrapping_mul(0x9E37_79B9))
            .wrapping_add(seed_offset),
    )
}

/// Descending-order index sort by key (keys are jittered so ties are
/// irrelevant in practice).
fn argsort_desc(keys: &[f64]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..keys.len()).collect();
    idx.sort_by(|&a, &b| keys[b].partial_cmp(&keys[a]).expect("finite sort keys"));
    idx
}

fn argsort_asc(keys: &[f64]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..keys.len()).collect();
    idx.sort_by(|&a, &b| keys[a].partial_cmp(&keys[b]).expect("finite sort keys"));
    idx
}

// ---------------------------------------------------------------------------
// smnist: geometry-derived shift×thickness anchor bank
// ---------------------------------------------------------------------------

/// Jitter bins matching the generator: dx ∈ [-2, 2], dy ∈ [-1, 1].
fn shift_bins() -> Vec<(i64, i64)> {
    let mut bins = Vec::with_capacity(15);
    for dy in -1i64..=1 {
        for dx in -2i64..=2 {
            bins.push((dx, dy));
        }
    }
    bins
}

/// Hidden bank [256 × H]: one neuron per (thickness, shift, class).
/// Returns the weights together with H so callers cannot desync from the
/// bank geometry.
fn smnist_hidden() -> (Vec<f64>, usize) {
    const C: usize = 10;
    const M: usize = smnist::INPUTS;
    let shifts = shift_bins();
    let h = C * shifts.len() * 2;
    let mut w1 = vec![0.0f64; M * h];
    let mut b = 0usize;
    for thick in [1i64, 2] {
        for &(dx, dy) in &shifts {
            let sup: Vec<[u8; M]> =
                (0..C).map(|c| smnist::support_map(c, dx, dy, thick)).collect();
            let dil: Vec<[u8; M]> =
                (0..C).map(|c| smnist::support_map(c, dx, dy, (thick + 1).min(2))).collect();
            let mut share = [0u32; M];
            let mut union2 = [0u8; M];
            for c in 0..C {
                for i in 0..M {
                    share[i] += sup[c][i] as u32;
                    union2[i] |= dil[c][i];
                }
            }
            for c in 0..C {
                let j = b * C + c;
                let mut rng = neuron_rng(j, 0);
                // Rank the template's cells by class-distinctiveness
                // (cells used by fewer classes rank higher).
                let cells: Vec<usize> = (0..M).filter(|&i| sup[c][i] > 0).collect();
                let dist: Vec<f64> = cells
                    .iter()
                    .map(|&i| (C as u32 - share[i]) as f64 + 0.001 * rng.uniform())
                    .collect();
                let order: Vec<usize> =
                    argsort_desc(&dist).into_iter().map(|k| cells[k]).collect();
                for &i in order.iter().take(6) {
                    w1[i * h + j] = ANCHOR_W * (0.95 + 0.1 * rng.uniform());
                }
                // Negatives on cells that belong to rival glyphs only
                // (dilated so thick-2 samples don't self-penalize).
                let negset: Vec<usize> =
                    (0..M).filter(|&i| union2[i] > 0 && dil[c][i] == 0).collect();
                let rival: Vec<f64> = negset
                    .iter()
                    .map(|&i| share[i] as f64 + 0.001 * rng.uniform())
                    .collect();
                let norder: Vec<usize> =
                    argsort_desc(&rival).into_iter().map(|k| negset[k]).collect();
                for &i in norder.iter().take(4) {
                    w1[i * h + j] = -SMNIST_ANCHOR_NEG_W * (0.9 + 0.2 * rng.uniform());
                }
                for &i in norder.iter().skip(4).take(8) {
                    w1[i * h + j] = -(0.12 + 0.08 * rng.uniform());
                }
            }
            b += 1;
        }
    }
    (w1, h)
}

// ---------------------------------------------------------------------------
// dvs / shd: prototype-estimated tiered matched filters
// ---------------------------------------------------------------------------

/// Class-mean spike-rate prototypes from the first K train samples per class.
fn prototypes(ds: Dataset, k_per_class: usize) -> Vec<Vec<f64>> {
    let c = ds.classes();
    let m = ds.inputs();
    let mut sums = vec![vec![0.0f64; m]; c];
    let mut counts = vec![0usize; c];
    let mut idx = 0u64;
    while counts.iter().min().copied().unwrap_or(0) < k_per_class
        && (idx as usize) < k_per_class * c * 8
    {
        let s = ds.sample(idx, Split::Train, T_STEPS);
        if counts[s.label] < k_per_class {
            for t in 0..s.t_steps {
                for (i, &sp) in s.step(t).iter().enumerate() {
                    if sp != 0 {
                        sums[s.label][i] += 1.0;
                    }
                }
            }
            counts[s.label] += 1;
        }
        idx += 1;
    }
    for (cls, row) in sums.iter_mut().enumerate() {
        let denom = (counts[cls].max(1) * T_STEPS) as f64;
        for v in row.iter_mut() {
            *v /= denom;
        }
    }
    sums
}

/// Hidden bank [M × (C · n_bins)] from rate prototypes (one tiered matched
/// filter per class, replicated per bin with jittered weights).
fn proto_hidden(ds: Dataset, n_bins: usize) -> Vec<f64> {
    let c = ds.classes();
    let m = ds.inputs();
    let protos = prototypes(ds, 20);
    let cross: Vec<f64> =
        (0..m).map(|i| protos.iter().map(|p| p[i]).sum::<f64>() / c as f64).collect();
    let h = c * n_bins;
    let seed_offset = if ds == Dataset::Dvs { 0u64 } else { 1u64 << 32 };
    let mut w1 = vec![0.0f64; m * h];
    for b in 0..n_bins {
        for cls in 0..c {
            let j = b * c + cls;
            let mut rng = neuron_rng(j, seed_offset);
            let d: Vec<f64> = (0..m).map(|i| protos[cls][i] - cross[i]).collect();
            let order = argsort_desc(&d);
            let mut w = vec![0.0f64; m];
            let anchors: Vec<usize> =
                order.iter().take(6).copied().filter(|&i| d[i] > 0.02).collect();
            for &i in &anchors {
                w[i] = ANCHOR_W * (0.95 + 0.1 * rng.uniform());
            }
            let fine: Vec<usize> =
                order.iter().skip(6).take(54).copied().filter(|&i| d[i] > 0.01).collect();
            let drive: f64 = w.iter().zip(&protos[cls]).map(|(a, p)| a * p).sum();
            if !fine.is_empty() {
                let mut base = vec![0.0f64; m];
                for &i in &fine {
                    base[i] = d[i] * (0.8 + 0.4 * rng.uniform());
                }
                let fd: f64 = base.iter().zip(&protos[cls]).map(|(a, p)| a * p).sum();
                if fd > 1e-9 {
                    let scale = (1.45 - drive).max(0.0) / fd;
                    for v in base.iter_mut() {
                        *v *= scale;
                    }
                }
                for (wi, bi) in w.iter_mut().zip(&base) {
                    *wi += bi.clamp(0.0, FINE_CAP);
                }
            }
            let ordern = argsort_asc(&d);
            let nanch: Vec<usize> =
                ordern.iter().take(4).copied().filter(|&i| d[i] < -0.02).collect();
            for &i in &nanch {
                w[i] = -PROTO_ANCHOR_NEG_W * (0.9 + 0.2 * rng.uniform());
            }
            let nfine: Vec<usize> =
                ordern.iter().skip(4).take(26).copied().filter(|&i| d[i] < -0.01).collect();
            if !nfine.is_empty() {
                let mut base = vec![0.0f64; m];
                for &i in &nfine {
                    base[i] = -d[i] * (0.8 + 0.4 * rng.uniform());
                }
                let pull: f64 = base.iter().zip(&cross).map(|(a, p)| a * p).sum();
                if pull > 1e-9 {
                    let scale = (0.9 / pull).min(1.0);
                    for v in base.iter_mut() {
                        *v *= scale;
                    }
                }
                for (wi, bi) in w.iter_mut().zip(&base) {
                    *wi -= bi.clamp(0.0, 0.18);
                }
            }
            for i in 0..m {
                w1[i * h + j] = w[i];
            }
        }
    }
    w1
}

/// Hand-structured pooling readout [H × C]: primary bins at ~0.5, secondary
/// bins at ~0.2, a few cross-class inhibition taps (used by dvs).
fn hand_readout(h: usize, c: usize, n_bins: usize) -> Vec<f64> {
    let mut w2 = vec![0.0f64; h * c];
    let mut rng = XorShift64Star::new(0x0077_0077);
    let n_primary = 6usize.min(n_bins);
    let mut prim: Vec<usize> = (0..n_primary)
        .map(|i| {
            (i as f64 * (n_bins - 1) as f64 / (n_primary - 1).max(1) as f64).round() as usize
        })
        .collect();
    prim.sort_unstable();
    prim.dedup();
    for cls in 0..c {
        for b in 0..n_bins {
            let j = b * c + cls;
            w2[j * c + cls] = if prim.contains(&b) {
                0.5 + 0.08 * (rng.uniform() - 0.5)
            } else {
                0.18 + 0.04 * rng.uniform()
            };
        }
        for r in 1..=4usize {
            let c2 = (cls + r * 3 + 1) % c;
            let b2 = (r * 2) % n_bins;
            w2[(b2 * c + c2) * c + cls] = -(0.15 + 0.05 * rng.uniform());
        }
    }
    w2
}

// ---------------------------------------------------------------------------
// Float forward passes (calibration + float_acc reference)
// ---------------------------------------------------------------------------

/// One float LIF layer step (decay 0.2, reset-by-subtraction) shared by the
/// count collector and the accuracy reference.
fn float_layer_step(
    w: &[f64],
    n: usize,
    active_in: &[usize],
    v: &mut [f64],
    vth: f64,
    spikes_out: &mut Vec<usize>,
    counts: Option<&mut [f64]>,
) {
    let mut act = vec![0.0f64; n];
    for &i in active_in {
        let row = &w[i * n..(i + 1) * n];
        for (a, wv) in act.iter_mut().zip(row) {
            *a += wv;
        }
    }
    spikes_out.clear();
    for j in 0..n {
        let leaked = v[j] - 0.2 * v[j];
        let mut vj = leaked + act[j];
        if vj >= vth {
            vj -= vth;
            spikes_out.push(j);
        }
        v[j] = vj;
    }
    if let Some(counts) = counts {
        for &j in spikes_out.iter() {
            counts[j] += 1.0;
        }
    }
}

/// Hidden spike counts of one sample through the float hidden bank.
fn hidden_counts(
    w1: &[f64],
    h: usize,
    sample: &crate::datasets::Sample,
    vth: f64,
) -> Vec<f64> {
    let mut v = vec![0.0f64; h];
    let mut counts = vec![0.0f64; h];
    let mut spikes = Vec::new();
    for t in 0..sample.t_steps {
        let active: Vec<usize> =
            sample.step(t).iter().enumerate().filter(|(_, &s)| s != 0).map(|(i, _)| i).collect();
        float_layer_step(w1, h, &active, &mut v, vth, &mut spikes, Some(&mut counts));
    }
    counts
}

/// Full float forward (both layers) → predicted class.
fn float_predict(model: &TrainedModel, sample: &crate::datasets::Sample) -> usize {
    let h = model.sizes[1];
    let c = model.sizes[2];
    let mut v1 = vec![0.0f64; h];
    let mut v2 = vec![0.0f64; c];
    let mut counts = vec![0.0f64; c];
    let mut sp1 = Vec::new();
    let mut sp2 = Vec::new();
    for t in 0..sample.t_steps {
        let active: Vec<usize> =
            sample.step(t).iter().enumerate().filter(|(_, &s)| s != 0).map(|(i, _)| i).collect();
        float_layer_step(&model.weights[0], h, &active, &mut v1, model.vth, &mut sp1, None);
        float_layer_step(&model.weights[1], c, &sp1, &mut v2, model.vth, &mut sp2, Some(&mut counts));
    }
    let mut best = 0;
    for (i, &x) in counts.iter().enumerate() {
        if x > counts[best] {
            best = i;
        }
    }
    best
}

// ---------------------------------------------------------------------------
// Ridge-regression readout
// ---------------------------------------------------------------------------

/// Solve A·X = B for X (A is n×n row-major, B is n×nc) by Gaussian
/// elimination with partial pivoting. A here is XᵀX + λI: symmetric positive
/// definite and well conditioned, so this is numerically safe.
fn solve_linear(a: &mut [f64], b: &mut [f64], n: usize, nc: usize) {
    for k in 0..n {
        // Partial pivot.
        let mut piv = k;
        for i in (k + 1)..n {
            if a[i * n + k].abs() > a[piv * n + k].abs() {
                piv = i;
            }
        }
        if piv != k {
            for col in 0..n {
                a.swap(k * n + col, piv * n + col);
            }
            for col in 0..nc {
                b.swap(k * nc + col, piv * nc + col);
            }
        }
        let diag = a[k * n + k];
        assert!(diag.abs() > 1e-12, "ridge system singular at row {k}");
        for i in (k + 1)..n {
            let f = a[i * n + k] / diag;
            if f == 0.0 {
                continue;
            }
            for col in k..n {
                a[i * n + col] -= f * a[k * n + col];
            }
            for col in 0..nc {
                b[i * nc + col] -= f * b[k * nc + col];
            }
        }
    }
    // Back substitution (result lands in b).
    for k in (0..n).rev() {
        let diag = a[k * n + k];
        for col in 0..nc {
            let mut acc = b[k * nc + col];
            for jj in (k + 1)..n {
                acc -= a[k * n + jj] * b[jj * nc + col];
            }
            b[k * nc + col] = acc / diag;
        }
    }
}

/// Fit the readout on hidden counts over generated training data, scale it,
/// and project it onto the fixed-point tier structure: per class at most 6
/// strong positive taps in [0.26, 0.6] and 4 strong negatives in
/// [-0.6, -0.26] (the Q3.1 survivors, wrap-safe by construction), everything
/// else capped to ±0.24 (alive at Q5.3, zero at Q3.1).
fn ridge_readout(ds: Dataset, w1: &[f64], h: usize, k_per_class: usize, vth: f64) -> Vec<f64> {
    const LAMBDA: f64 = 50.0;
    const GAMMA: f64 = 15.0;
    let c = ds.classes();
    let mut xs: Vec<Vec<f64>> = Vec::new();
    let mut labels: Vec<usize> = Vec::new();
    let mut counts = vec![0usize; c];
    let mut idx = 0u64;
    while counts.iter().min().copied().unwrap_or(0) < k_per_class
        && (idx as usize) < k_per_class * c * 8
    {
        let s = ds.sample(idx, Split::Train, T_STEPS);
        if counts[s.label] < k_per_class {
            xs.push(hidden_counts(w1, h, &s, vth));
            labels.push(s.label);
            counts[s.label] += 1;
        }
        idx += 1;
    }
    // A = XᵀX + λI, B = XᵀY.
    let mut a = vec![0.0f64; h * h];
    let mut b = vec![0.0f64; h * c];
    for (x, &l) in xs.iter().zip(&labels) {
        for i in 0..h {
            if x[i] == 0.0 {
                continue;
            }
            for j in 0..h {
                a[i * h + j] += x[i] * x[j];
            }
            b[i * c + l] += x[i];
        }
    }
    for i in 0..h {
        a[i * h + i] += LAMBDA;
    }
    solve_linear(&mut a, &mut b, h, c);
    // Scale + tier projection.
    let mut w2 = vec![0.0f64; h * c];
    for cls in 0..c {
        let col: Vec<f64> = (0..h).map(|j| b[j * c + cls] * GAMMA).collect();
        let order = argsort_desc(&col);
        for (rank, &j) in order.iter().enumerate() {
            let v = col[j];
            if v > 0.0 {
                w2[j * c + cls] =
                    if rank < 6 { v.clamp(0.26, 0.6) } else { v.min(0.24) };
            }
        }
        let ordern = argsort_asc(&col);
        for (rank, &j) in ordern.iter().enumerate() {
            let v = col[j];
            if v < 0.0 {
                w2[j * c + cls] =
                    if rank < 4 { v.clamp(-0.6, -0.26) } else { v.max(-0.24) };
            }
        }
    }
    w2
}

// ---------------------------------------------------------------------------
// Public entry point
// ---------------------------------------------------------------------------

/// Calibrate one dataset's model (hidden bank + readout + float accuracy).
pub fn train(ds: Dataset) -> TrainedModel {
    let m = ds.inputs();
    let c = ds.classes();
    let vth = deploy_vth(ds);
    let (w1, h) = match ds {
        Dataset::Smnist => smnist_hidden(),
        Dataset::Dvs => {
            let n_bins = 20;
            (proto_hidden(ds, n_bins), c * n_bins)
        }
        Dataset::Shd => {
            let n_bins = 14;
            (proto_hidden(ds, n_bins), c * n_bins)
        }
    };
    let w2 = match ds {
        Dataset::Smnist => ridge_readout(ds, &w1, h, 60, vth),
        Dataset::Dvs => hand_readout(h, c, 20),
        Dataset::Shd => ridge_readout(ds, &w1, h, 20, vth),
    };
    let mut model = TrainedModel {
        dataset: ds,
        sizes: vec![m, h, c],
        t_steps: T_STEPS,
        vth,
        weights: vec![w1, w2],
        float_acc: 0.0,
    };
    let n_eval = if ds == Dataset::Smnist { 100 } else { 40 };
    let mut correct = 0usize;
    for i in 0..n_eval {
        let s = ds.sample(i as u64, Split::Test, T_STEPS);
        if float_predict(&model, &s) == s.label {
            correct += 1;
        }
    }
    model.float_acc = correct as f64 / n_eval as f64;
    model
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::{Q3_1, Q5_3};

    #[test]
    fn solver_inverts_small_system() {
        // A = [[2,1],[1,3]], B = [[5],[10]] -> x = [1, 3].
        let mut a = vec![2.0, 1.0, 1.0, 3.0];
        let mut b = vec![5.0, 10.0];
        solve_linear(&mut a, &mut b, 2, 1);
        assert!((b[0] - 1.0).abs() < 1e-12 && (b[1] - 3.0).abs() < 1e-12, "{b:?}");
    }

    #[test]
    fn smnist_bank_is_wrap_safe() {
        let (w1, h) = smnist_hidden();
        assert_eq!(h, 300, "bank geometry: 10 classes x 15 shifts x 2 thicknesses");
        for j in 0..h {
            let (mut pos, mut neg) = (0.0f64, 0.0f64);
            for i in 0..smnist::INPUTS {
                let q = Q3_1.to_float(Q3_1.from_float(w1[i * h + j]));
                if q > 0.0 {
                    pos += q;
                } else {
                    neg += q;
                }
            }
            // Q3.1 value range is [-4, 3.5]; simultaneous activation of every
            // positive (or negative) input must not wrap the act register.
            assert!(pos <= 3.5 + 1e-9, "neuron {j}: Q3.1 positive mass {pos}");
            assert!(neg >= -4.0 - 1e-9, "neuron {j}: Q3.1 negative mass {neg}");
        }
    }

    #[test]
    fn anchors_survive_q31_and_fine_survives_q53() {
        let (w1, _h) = smnist_hidden();
        let mut q31_alive = 0usize;
        let mut q53_alive = 0usize;
        let mut total = 0usize;
        for v in w1.iter().filter(|v| **v != 0.0) {
            total += 1;
            if Q3_1.from_float(*v) != 0 {
                q31_alive += 1;
            }
            if Q5_3.from_float(*v) != 0 {
                q53_alive += 1;
            }
        }
        assert_eq!(q53_alive, total, "every nonzero weight must survive Q5.3");
        assert!(q31_alive > 0 && q31_alive < total, "Q3.1 must keep only the anchor tier");
    }
}
