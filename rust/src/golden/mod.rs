//! Native golden-vector + artifact substrate.
//!
//! The seed repo assumed `make artifacts` ran a Python/JAX build step to
//! produce `artifacts/` (manifest, weights, golden vectors). This module
//! regenerates the whole store natively from the in-crate reference
//! implementations, so a fresh checkout builds, tests, and serves with no
//! Python anywhere:
//!
//! * `golden_fixedpoint.json` — Qn.q add/sub/mul vectors from
//!   [`crate::fixed`]. Note the pinning semantics: on a machine where the
//!   store persists, a later semantic change to the arithmetic trips the
//!   parity tests against the recorded vectors; a fresh checkout
//!   regenerates vectors and implementation together, so cross-*version*
//!   drift is caught, cross-*implementation* drift (vs the optional Python
//!   reference) is only caught when a Python-built store is present.
//! * `golden_lif_q53.json` / `golden_lif_q97.json` — multi-step LIF layer
//!   traces for all four Eq. 7 reset modes from [`crate::hdl::Layer`].
//! * `golden_datasets.json` — determinism pins for the three synthetic
//!   dataset generators.
//! * `manifest.json` + per-variant quantized weight files + the float
//!   reference weights — produced by the native calibrator in [`train`]
//!   (smnist at Q9.7/Q5.3/Q3.1; dvs and shd at Q5.3), in exactly the JSON
//!   schema [`crate::runtime::artifacts::Manifest`] parses.
//!
//! Weight files are serialized **dense** (`[M × N]` row-major, zeros at
//! pruned positions) regardless of topology — the on-disk contract is the
//! dense view. At load time `SynapticMemory::load_dense` scatters each
//! matrix into the layer's topology-aware store (banded for Gaussian,
//! diagonal for one-to-one), so the artifact format is stable while the
//! in-memory representation is sparse.
//!
//! [`ensure_artifacts`] is the idempotent entry point used by tests,
//! examples, and the CLI: it generates the store once per process (and
//! skips generation entirely when a store with the current
//! [`GOLDEN_VERSION`] already exists on disk).

pub mod train;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::OnceLock;

use anyhow::{Context, Result};

use crate::config::registers::{RegisterFile, ResetMode, REG_REFRACTORY, REG_RESET_MODE, REG_VRESET, REG_VTH};
use crate::config::{LayerConfig, MemKind, Topology};
use crate::datasets::rng::XorShift64Star;
use crate::datasets::{Dataset, Split};
use crate::fixed::{QSpec, Q17_15, Q2_2, Q3_1, Q5_3, Q9_7};
use crate::hdl::Layer;
use crate::util::json::Json;

/// Version tag embedded in `manifest.json`; bump when the generator or the
/// calibration algorithm changes so stale stores are rebuilt.
pub const GOLDEN_VERSION: &str = "native-golden-v1";

/// Idempotent artifact bootstrap: returns the artifacts directory,
/// generating the store first if it is missing or stale. Safe to call from
/// concurrent tests within one process (the mutex makes generation run
/// once); failures are *not* cached, so a transient error (disk full,
/// permissions) can be retried on the next call.
pub fn ensure_artifacts() -> Result<PathBuf> {
    static READY: OnceLock<PathBuf> = OnceLock::new();
    static BUILDING: std::sync::Mutex<()> = std::sync::Mutex::new(());
    if let Some(p) = READY.get() {
        return Ok(p.clone());
    }
    let _guard = BUILDING.lock().unwrap_or_else(|poison| poison.into_inner());
    if let Some(p) = READY.get() {
        return Ok(p.clone());
    }
    let dir = crate::artifacts_dir();
    match store_state(&dir) {
        // A foreign store (e.g. built by the Python AOT path) is trusted
        // as-is — auto-bootstrap must never destroy trained artifacts.
        StoreState::Current | StoreState::Foreign => {}
        StoreState::Missing | StoreState::StaleNative => {
            generate(&dir).context("generating artifacts")?;
        }
    }
    let _ = READY.set(dir.clone());
    Ok(dir)
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum StoreState {
    /// No parseable manifest.
    Missing,
    /// Native store at the current generator version.
    Current,
    /// Native store from an older generator version.
    StaleNative,
    /// A manifest without a `version` key — produced by something other
    /// than this generator (e.g. the optional Python AOT path). Never
    /// auto-clobbered; only an explicit [`generate`] replaces it.
    Foreign,
}

fn store_state(dir: &Path) -> StoreState {
    let path = dir.join("manifest.json");
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return StoreState::Missing,
        // Unreadable (permissions, transient I/O): treat as foreign so the
        // auto-bootstrap never deletes a store it cannot inspect; the
        // subsequent Manifest::load reports the real error.
        Err(_) => return StoreState::Foreign,
    };
    let Ok(json) = Json::parse(&text) else {
        // A manifest that exists but does not parse is a half-written or
        // damaged native store: safe to rebuild.
        return StoreState::StaleNative;
    };
    match json.get("version").and_then(|v| v.as_str()) {
        Some(v) if v == GOLDEN_VERSION => StoreState::Current,
        Some(_) => StoreState::StaleNative,
        None => StoreState::Foreign,
    }
}

fn store_is_current(dir: &Path) -> bool {
    store_state(dir) == StoreState::Current
}

/// Regenerate the full artifact store at `dir`, unconditionally replacing
/// whatever is there (build in a sibling temp directory, then swap into
/// place). This is the forced path behind `repro artifacts` /
/// `make artifacts`, so it must repair a store whose manifest is current
/// but whose data files are damaged; the only concession to a concurrent
/// generator is the rename-failure fallback.
pub fn generate(dir: &Path) -> Result<()> {
    let parent = dir.parent().unwrap_or_else(|| Path::new("."));
    std::fs::create_dir_all(parent)
        .with_context(|| format!("creating {}", parent.display()))?;
    let tmp = parent.join(format!(
        ".artifacts-build-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&tmp);
    std::fs::create_dir_all(&tmp).with_context(|| format!("creating {}", tmp.display()))?;
    let result = generate_into(&tmp);
    if result.is_err() {
        let _ = std::fs::remove_dir_all(&tmp);
        return result;
    }
    // Swap in with two renames (move the old store aside, move the new one
    // in, delete the old one afterwards) so the window in which `dir` is
    // absent is two metadata operations, not a recursive delete.
    let old = parent.join(format!(".artifacts-old-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&old);
    if dir.exists() {
        std::fs::rename(dir, &old)
            .with_context(|| format!("moving stale store {} aside", dir.display()))?;
    }
    match std::fs::rename(&tmp, dir) {
        Ok(()) => {
            let _ = std::fs::remove_dir_all(&old);
            Ok(())
        }
        Err(e) => {
            let _ = std::fs::remove_dir_all(&tmp);
            // A concurrent generator may have installed a store in the
            // window; accept it. Otherwise try to restore the old store.
            if store_is_current(dir) {
                let _ = std::fs::remove_dir_all(&old);
                Ok(())
            } else {
                let _ = std::fs::rename(&old, dir);
                Err(anyhow::anyhow!("installing artifacts at {}: {e}", dir.display()))
            }
        }
    }
}

fn generate_into(dir: &Path) -> Result<()> {
    write_json(&dir.join("golden_fixedpoint.json"), &fixedpoint_golden())?;
    write_json(&dir.join("golden_lif_q53.json"), &lif_golden(Q5_3))?;
    write_json(&dir.join("golden_lif_q97.json"), &lif_golden(Q9_7))?;
    write_json(&dir.join("golden_datasets.json"), &datasets_golden())?;

    std::fs::create_dir_all(dir.join("hlo"))?;
    std::fs::create_dir_all(dir.join("kernels"))?;
    let placeholder = "// HLO text artifacts are produced by the optional Python AOT path\n\
                       // (python/compile/aot.py). The native build serves through the\n\
                       // cycle-accurate hdl core; the PJRT runtime is gated on `--features pjrt`.\n";
    std::fs::write(dir.join("kernels/lif_step_Q53.hlo"), placeholder)?;

    let mut models = BTreeMap::new();
    for ds in Dataset::all() {
        let model = train::train(ds);
        let variants: &[QSpec] = match ds {
            Dataset::Smnist => &[Q9_7, Q5_3, Q3_1],
            _ => &[Q5_3],
        };
        models.insert(ds.label().to_string(), model_entry(dir, &model, variants, placeholder)?);
    }

    let mut kernels = BTreeMap::new();
    kernels.insert(
        "lif_step_Q53".to_string(),
        obj(vec![("file", Json::Str("kernels/lif_step_Q53.hlo".into()))]),
    );

    let manifest = obj(vec![
        ("version", Json::Str(GOLDEN_VERSION.into())),
        ("generator", Json::Str("quantisenc::golden (native, no Python)".into())),
        ("models", Json::Obj(models)),
        ("kernels", Json::Obj(kernels)),
    ]);
    write_json(&dir.join("manifest.json"), &manifest)?;
    Ok(())
}

/// One manifest model entry + its weight files on disk.
fn model_entry(
    dir: &Path,
    model: &train::TrainedModel,
    variants: &[QSpec],
    hlo_placeholder: &str,
) -> Result<Json> {
    let ds = model.dataset;
    let layer_shapes: Vec<(usize, usize)> = model
        .sizes
        .windows(2)
        .map(|w| (w[0], w[1]))
        .collect();

    // Float ("software") reference weights for smnist (Fig. 12 RMSE).
    if ds == Dataset::Smnist {
        let mut bytes = Vec::new();
        for w in &model.weights {
            for &v in w {
                bytes.extend_from_slice(&(v as f32).to_le_bytes());
            }
        }
        std::fs::write(dir.join("weights_smnist_float.bin"), bytes)?;
    }

    let mut variant_map = BTreeMap::new();
    for &qs in variants {
        let qname = qs.name();
        let wfile = format!("weights_{}_{}.bin", ds.label(), qname);
        let mut bytes = Vec::new();
        for w in &model.weights {
            for &v in w {
                bytes.extend_from_slice(&qs.from_float(v).to_le_bytes());
            }
        }
        std::fs::write(dir.join(&wfile), bytes)?;

        let hlo_rel = format!("hlo/{}_{}.hlo", ds.label(), qname);
        std::fs::write(dir.join(&hlo_rel), hlo_placeholder)?;

        let mut regs = RegisterFile::new(qs);
        regs.write(REG_VTH, qs.from_float(model.vth))
            .expect("deployment vth must be representable");
        let regs_json =
            Json::Arr(regs.vector().iter().map(|&v| Json::Num(v as f64)).collect());

        variant_map.insert(
            qname,
            obj(vec![
                ("hlo", Json::Str(hlo_rel)),
                (
                    "layer_shapes",
                    Json::Arr(
                        layer_shapes
                            .iter()
                            .map(|&(m, n)| {
                                Json::Arr(vec![Json::Num(m as f64), Json::Num(n as f64)])
                            })
                            .collect(),
                    ),
                ),
                ("default_regs", regs_json),
                ("weights", Json::Str(wfile)),
            ]),
        );
    }

    Ok(obj(vec![
        (
            "sizes",
            Json::Arr(model.sizes.iter().map(|&s| Json::Num(s as f64)).collect()),
        ),
        ("t_steps", Json::Num(model.t_steps as f64)),
        ("float_acc", Json::Num(model.float_acc)),
        ("variants", Json::Obj(variant_map)),
    ]))
}

// ---------------------------------------------------------------------------
// Golden vector generators
// ---------------------------------------------------------------------------

/// 256 add/sub/mul cases cycling through the paper's quantization settings.
fn fixedpoint_golden() -> Json {
    let specs = [Q2_2, Q3_1, Q5_3, Q9_7, Q17_15];
    let mut rng = XorShift64Star::new(0xF1CED_0077);
    let mut cases = Vec::with_capacity(256);
    for k in 0..256usize {
        let qs = specs[k % specs.len()];
        let a = qs.wrap(rng.next_u64() as i64);
        let b = qs.wrap(rng.next_u64() as i64);
        cases.push(obj(vec![
            ("q", Json::Str(qs.name())),
            ("a", Json::Num(a as f64)),
            ("b", Json::Num(b as f64)),
            ("add", Json::Num(qs.add(a, b) as f64)),
            ("sub", Json::Num(qs.sub(a, b) as f64)),
            ("mul", Json::Num(qs.mul(a, b) as f64)),
        ]));
    }
    obj(vec![("cases", Json::Arr(cases))])
}

/// Multi-step LIF layer traces (all four reset modes) for one quantization.
fn lif_golden(qs: QSpec) -> Json {
    let (m, n, t_steps) = (6usize, 4usize, 12usize);
    let mut rng = XorShift64Star::new(0x11F_0000 + qs.width() as u64);
    let weights: Vec<i32> =
        (0..m * n).map(|_| qs.from_float(2.0 * rng.uniform() - 1.0)).collect();
    let spikes_in: Vec<Vec<i32>> = (0..t_steps)
        .map(|_| (0..m).map(|_| (rng.uniform() < 0.4) as i32).collect())
        .collect();

    let mut traces = BTreeMap::new();
    for mode in ResetMode::all() {
        let mut regs = RegisterFile::new(qs);
        regs.write(REG_RESET_MODE, mode as i32).unwrap();
        if mode == ResetMode::ToConstant {
            regs.write(REG_VRESET, qs.from_float(0.25)).unwrap();
        }
        if mode == ResetMode::ToZero {
            regs.write(REG_REFRACTORY, 2).unwrap();
        }
        let cfg = LayerConfig { fan_in: m, neurons: n, topology: Topology::AllToAll };
        let mut layer = Layer::new(&cfg, qs, MemKind::Bram);
        layer.memory_mut().load_dense(&weights).unwrap();
        let mut out = Vec::new();
        let mut spikes_out = Vec::with_capacity(t_steps);
        let mut vmem = Vec::with_capacity(t_steps);
        for row in &spikes_in {
            let row_u8: Vec<u8> = row.iter().map(|&x| x as u8).collect();
            layer.step_regs(&row_u8, &mut out, &regs);
            spikes_out.push(Json::Arr(out.iter().map(|&s| Json::Num(s as f64)).collect()));
            let vm = layer.vmem_slice();
            vmem.push(Json::Arr(vm.iter().map(|&v| Json::Num(v as f64)).collect()));
        }
        let key = match mode {
            ResetMode::Default => "default",
            ResetMode::ToZero => "to_zero",
            ResetMode::BySubtraction => "by_subtraction",
            ResetMode::ToConstant => "to_constant",
        };
        traces.insert(
            key.to_string(),
            obj(vec![
                (
                    "regs",
                    Json::Arr(regs.vector().iter().map(|&v| Json::Num(v as f64)).collect()),
                ),
                ("spikes_out", Json::Arr(spikes_out)),
                ("vmem", Json::Arr(vmem)),
            ]),
        );
    }

    let weight_rows: Vec<Json> = (0..m)
        .map(|i| {
            Json::Arr(weights[i * n..(i + 1) * n].iter().map(|&w| Json::Num(w as f64)).collect())
        })
        .collect();
    obj(vec![
        ("q", Json::Str(qs.name())),
        ("m", Json::Num(m as f64)),
        ("n", Json::Num(n as f64)),
        ("weights", Json::Arr(weight_rows)),
        (
            "spikes_in",
            Json::Arr(
                spikes_in
                    .iter()
                    .map(|r| Json::Arr(r.iter().map(|&x| Json::Num(x as f64)).collect()))
                    .collect(),
            ),
        ),
        ("traces", Json::Obj(traces)),
    ])
}

/// Determinism pins for the three dataset generators.
fn datasets_golden() -> Json {
    let t = 12usize;
    let mut entries = BTreeMap::new();
    for ds in Dataset::all() {
        let s = ds.sample(0, Split::Test, t);
        let rows: Vec<Json> =
            s.row_counts().iter().map(|&x| Json::Num(x as f64)).collect();
        let first: Vec<Json> = (0..s.inputs)
            .filter(|&i| s.spike(0, i) == 1)
            .map(|i| Json::Num(i as f64))
            .collect();
        entries.insert(
            ds.label().to_string(),
            obj(vec![
                ("t", Json::Num(t as f64)),
                ("label", Json::Num(s.label as f64)),
                ("spike_rows", Json::Arr(rows)),
                ("first_row_indices", Json::Arr(first)),
                ("nnz", Json::Num(s.nnz() as f64)),
            ]),
        );
    }
    Json::Obj(entries)
}

// ---------------------------------------------------------------------------
// Small helpers
// ---------------------------------------------------------------------------

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_json(path: &Path, json: &Json) -> Result<()> {
    std::fs::write(path, json.to_string())
        .with_context(|| format!("writing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixedpoint_golden_shape_and_selfparity() {
        let g = fixedpoint_golden();
        let cases = g.get("cases").unwrap().as_arr().unwrap();
        assert_eq!(cases.len(), 256);
        for c in cases {
            let qs = QSpec::parse(c.get("q").unwrap().as_str().unwrap()).unwrap();
            let a = c.get("a").unwrap().as_i64().unwrap() as i32;
            let b = c.get("b").unwrap().as_i64().unwrap() as i32;
            assert!(qs.in_range(a) && qs.in_range(b));
            assert_eq!(qs.add(a, b) as i64, c.get("add").unwrap().as_i64().unwrap());
        }
    }

    #[test]
    fn lif_golden_covers_all_reset_modes() {
        let g = lif_golden(Q5_3);
        let traces = g.get("traces").unwrap().as_obj().unwrap();
        assert_eq!(traces.len(), 4);
        for key in ["default", "to_zero", "by_subtraction", "to_constant"] {
            let tr = traces.get(key).unwrap();
            assert_eq!(tr.get("spikes_out").unwrap().as_arr().unwrap().len(), 12);
            assert_eq!(tr.get("vmem").unwrap().as_arr().unwrap().len(), 12);
            assert_eq!(tr.get("regs").unwrap().i32_vec().unwrap().len(), 6);
        }
        // Round-trips through the strict JSON parser.
        let reparsed = Json::parse(&g.to_string()).unwrap();
        assert_eq!(reparsed.get("m").unwrap().as_i64(), Some(6));
    }

    #[test]
    fn datasets_golden_is_deterministic() {
        let a = datasets_golden().to_string();
        let b = datasets_golden().to_string();
        assert_eq!(a, b);
        let j = Json::parse(&a).unwrap();
        for ds in Dataset::all() {
            let e = j.get(ds.label()).unwrap();
            assert_eq!(e.get("t").unwrap().as_i64(), Some(12));
            assert!(e.get("nnz").unwrap().as_i64().unwrap() > 0);
        }
    }
}
