//! Spiking-MNIST stand-in: procedural 16×16 digit glyphs, rate-encoded.
//!
//! **Bit-identical** to `datasets.smnist_sample` in Python: same
//! seven-segment geometry, same PRNG call order (label → glyph jitter →
//! per-cell intensities → dropout/noise → Poisson encoding), no
//! transcendental math anywhere. Digit 8's segments are a superset of 3's
//! and 0's, preserving the paper's Fig.-11 confusion structure.

use super::{sample_rng, Sample, Split, XorShift64Star};

pub const GRID: usize = 16;
pub const INPUTS: usize = GRID * GRID;
pub const CLASSES: usize = 10;

/// digit → active segments (0=top, 1=top-left, 2=top-right, 3=middle,
/// 4=bottom-left, 5=bottom-right, 6=bottom). Order matters for PRNG parity.
const SEGMENTS: [&[u8]; 10] = [
    &[0, 1, 2, 4, 5, 6],
    &[2, 5],
    &[0, 2, 3, 4, 6],
    &[0, 2, 3, 5, 6],
    &[1, 2, 3, 5],
    &[0, 1, 3, 5, 6],
    &[0, 1, 3, 4, 5, 6],
    &[0, 2, 5],
    &[0, 1, 2, 3, 4, 5, 6],
    &[0, 1, 2, 3, 5, 6],
];

/// Cells of one glyph segment (same enumeration order as Python's
/// `_segment_cells`): base cells first, then thickness expansion.
fn segment_cells(seg: u8, dx: i64, dy: i64, thick: i64) -> Vec<(i64, i64)> {
    let (x0, x1, ym, y0, y1) = (4i64, 11i64, 8i64, 2i64, 13i64);
    let cells: Vec<(i64, i64)> = match seg {
        0 => (x0..=x1).map(|x| (x, y0)).collect(),
        6 => (x0..=x1).map(|x| (x, y1)).collect(),
        3 => (x0..=x1).map(|x| (x, ym)).collect(),
        1 => (y0..=ym).map(|y| (x0, y)).collect(),
        2 => (y0..=ym).map(|y| (x1, y)).collect(),
        4 => (ym..=y1).map(|y| (x0, y)).collect(),
        5 => (ym..=y1).map(|y| (x1, y)).collect(),
        _ => unreachable!("segment id 0..=6"),
    };
    let mut out = Vec::with_capacity(cells.len() * (thick * thick) as usize);
    for (x, y) in cells {
        for tx in 0..thick {
            for ty in 0..thick {
                out.push((x + dx + tx, y + dy + ty));
            }
        }
    }
    out
}

/// Binary support map (row-major, 256 cells) of a digit glyph at jitter
/// (dx, dy) and stroke thickness. This is the generator geometry the
/// native calibrator in [`crate::golden`] builds its matched filters from
/// (the software-stack equivalent of training against the generator).
pub(crate) fn support_map(digit: usize, dx: i64, dy: i64, thick: i64) -> [u8; INPUTS] {
    assert!(digit < CLASSES, "digit out of range: {digit}");
    let mut m = [0u8; INPUTS];
    for &seg in SEGMENTS[digit] {
        for (x, y) in segment_cells(seg, dx, dy, thick) {
            if (0..GRID as i64).contains(&x) && (0..GRID as i64).contains(&y) {
                m[y as usize * GRID + x as usize] = 1;
            }
        }
    }
    m
}

/// One jittered glyph image as 256 intensities in [0, 1] (row-major).
pub fn digit_image(digit: usize, rng: &mut XorShift64Star) -> [f64; INPUTS] {
    assert!(digit < CLASSES, "digit out of range: {digit}");
    let mut img = [0.0f64; INPUTS];
    let dx = rng.below(5) as i64 - 2;
    let dy = rng.below(3) as i64 - 1;
    let thick = 1 + rng.below(2) as i64;
    for &seg in SEGMENTS[digit] {
        for (x, y) in segment_cells(seg, dx, dy, thick) {
            if (0..GRID as i64).contains(&x) && (0..GRID as i64).contains(&y) {
                img[y as usize * GRID + x as usize] = 0.75 + 0.25 * rng.uniform();
            }
        }
    }
    // Dropout + background noise (same short-circuit order as Python).
    for i in 0..INPUTS {
        if img[i] > 0.0 {
            if rng.uniform() < 0.08 {
                img[i] = 0.0;
            }
        } else if rng.uniform() < 0.02 {
            img[i] = 0.3 * rng.uniform();
        }
    }
    img
}

/// Poisson rate coding: spike[t, i] ~ Bernoulli(intensity_i · max_rate).
pub fn rate_encode(
    image: &[f64],
    t_steps: usize,
    rng: &mut XorShift64Star,
    max_rate: f64,
) -> Vec<u8> {
    let n = image.len();
    let mut spikes = vec![0u8; t_steps * n];
    for t in 0..t_steps {
        for i in 0..n {
            if image[i] > 0.0 && rng.uniform() < image[i] * max_rate {
                spikes[t * n + i] = 1;
            }
        }
    }
    spikes
}

pub fn sample(index: u64, split: Split, t_steps: usize, seed: u64) -> Sample {
    let mut rng = sample_rng(0x5EED_0000, seed, index, split);
    let label = rng.below(CLASSES as u64) as usize;
    let img = digit_image(label, &mut rng);
    let spikes = rate_encode(&img, t_steps, &mut rng, 0.5);
    Sample { spikes, t_steps, inputs: INPUTS, label }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn glyph_confusion_structure() {
        // Paper Fig. 11: digit 8 shares all segments with 3 and 0.
        let s8: std::collections::HashSet<u8> = SEGMENTS[8].iter().copied().collect();
        assert!(SEGMENTS[3].iter().all(|s| s8.contains(s)));
        assert!(SEGMENTS[0].iter().all(|s| s8.contains(s)));
    }

    #[test]
    fn distinct_templates() {
        let set: std::collections::HashSet<_> = SEGMENTS.iter().collect();
        assert_eq!(set.len(), 10);
    }

    #[test]
    fn image_in_unit_range() {
        let mut rng = XorShift64Star::new(5);
        let img = digit_image(8, &mut rng);
        assert!(img.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(img.iter().sum::<f64>() > 0.0);
    }

    #[test]
    fn rate_scales_with_max_rate() {
        let img = [1.0f64; 16];
        let mut r1 = XorShift64Star::new(1);
        let mut r2 = XorShift64Star::new(1);
        let low: usize = rate_encode(&img, 200, &mut r1, 0.1).iter().map(|&x| x as usize).sum();
        let high: usize = rate_encode(&img, 200, &mut r2, 0.9).iter().map(|&x| x as usize).sum();
        assert!(high > low);
    }

    #[test]
    #[should_panic(expected = "digit out of range")]
    fn rejects_bad_digit() {
        digit_image(10, &mut XorShift64Star::new(1));
    }

    #[test]
    fn sample_smoke() {
        let s = sample(0, Split::Test, 8, 7);
        assert_eq!(s.inputs, 256);
        assert!(s.nnz() > 0);
    }
}
