//! DVS-Gesture stand-in: a Gaussian event blob sweeping a 20×20 grid in 11
//! motion classes (8 linear directions, 2 rotation senses, 1 random walk).
//! Mirrors `datasets.dvs_sample` in Python (same PRNG call order).

use super::{sample_rng, Sample, Split};

pub const GRID: usize = 20;
pub const INPUTS: usize = GRID * GRID;
pub const CLASSES: usize = 11;

pub fn sample(index: u64, split: Split, t_steps: usize, seed: u64) -> Sample {
    let mut rng = sample_rng(0xD4E5_0000, seed, index, split);
    let g = GRID as f64;
    let label = rng.below(CLASSES as u64) as usize;
    let mut spikes = vec![0u8; t_steps * INPUTS];
    let cx = g / 2.0 + rng.below(5) as f64 - 2.0;
    let cy = g / 2.0 + rng.below(5) as f64 - 2.0;

    #[derive(PartialEq)]
    enum Mode {
        Linear { vx: f64, vy: f64 },
        Rotate { sense: f64 },
        Walk,
    }
    let mode = if label < 8 {
        let ang = 2.0 * std::f64::consts::PI * label as f64 / 8.0 + 0.2 * (rng.uniform() - 0.5);
        Mode::Linear { vx: 0.45 * ang.cos(), vy: 0.45 * ang.sin() }
    } else if label < 10 {
        Mode::Rotate { sense: if label == 8 { 1.0 } else { -1.0 } }
    } else {
        Mode::Walk
    };

    let (mut x, mut y) = (cx, cy);
    let mut phase = 2.0 * std::f64::consts::PI * rng.uniform();
    for t in 0..t_steps {
        match mode {
            Mode::Linear { vx, vy } => {
                x = (x + vx).rem_euclid(g);
                y = (y + vy).rem_euclid(g);
            }
            Mode::Rotate { sense } => {
                phase += sense * 0.35;
                x = cx + 5.5 * phase.cos();
                y = cy + 5.5 * phase.sin();
            }
            Mode::Walk => {
                x = (x + (rng.uniform() - 0.5) * 3.0).rem_euclid(g);
                y = (y + (rng.uniform() - 0.5) * 3.0).rem_euclid(g);
            }
        }
        let ywrap = y.rem_euclid(g);
        let xwrap = x.rem_euclid(g);
        for i in 0..GRID {
            for j in 0..GRID {
                let d2 = (i as f64 - ywrap).powi(2) + (j as f64 - xwrap).powi(2);
                let p = 0.9 * (-d2 / 3.0).exp();
                if p > 0.02 && rng.uniform() < p {
                    spikes[t * INPUTS + i * GRID + j] = 1;
                }
            }
        }
    }
    Sample { spikes, t_steps, inputs: INPUTS, label }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blob_is_localised_per_step() {
        // Each timestep's events cluster near one centre: the bounding box
        // of active cells is far smaller than the grid for linear sweeps.
        let s = sample(1, Split::Train, 10, 11);
        for t in 0..10 {
            let active: Vec<(usize, usize)> = (0..INPUTS)
                .filter(|&i| s.spike(t, i) == 1)
                .map(|i| (i / GRID, i % GRID))
                .collect();
            if active.len() > 3 {
                let (si, sj): (Vec<_>, Vec<_>) = active.iter().copied().unzip();
                let spread = (si.iter().max().unwrap() - si.iter().min().unwrap())
                    .min(sj.iter().max().unwrap() - sj.iter().min().unwrap());
                assert!(spread <= 12, "t={t} spread {spread}");
            }
        }
    }

    #[test]
    fn linear_classes_move() {
        // For a linear class the active centroid at t=0 and t=19 differ.
        for idx in 0..30u64 {
            let s = sample(idx, Split::Train, 20, 11);
            if s.label < 8 && s.row_counts()[0] > 0 && s.row_counts()[19] > 0 {
                let centroid = |t: usize| {
                    let pts: Vec<usize> = (0..INPUTS).filter(|&i| s.spike(t, i) == 1).collect();
                    let n = pts.len() as f64;
                    (
                        pts.iter().map(|&i| (i / GRID) as f64).sum::<f64>() / n,
                        pts.iter().map(|&i| (i % GRID) as f64).sum::<f64>() / n,
                    )
                };
                let (a, b) = (centroid(0), centroid(19));
                let d = ((a.0 - b.0).powi(2) + (a.1 - b.1).powi(2)).sqrt();
                assert!(d > 0.3, "idx {idx} moved only {d}");
                return;
            }
        }
        panic!("no linear sample found in 30 draws");
    }

    #[test]
    fn all_classes_produce_events() {
        for i in 0..40 {
            let s = sample(i, Split::Test, 8, 11);
            assert!(s.nnz() > 0, "sample {i} empty");
        }
    }
}
