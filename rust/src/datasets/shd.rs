//! SHD stand-in: 700-channel spectro-temporal ridge patterns, 20 classes —
//! three formant-like channel trajectories per class. Mirrors
//! `datasets.shd_sample` in Python (same PRNG call order).

use super::{sample_rng, Sample, Split};

pub const INPUTS: usize = 700;
pub const CLASSES: usize = 20;

pub fn sample(index: u64, split: Split, t_steps: usize, seed: u64) -> Sample {
    let mut rng = sample_rng(0x54D0_0000, seed, index, split);
    let label = rng.below(CLASSES as u64) as usize;
    let mut spikes = vec![0u8; t_steps * INPUTS];
    let t_f = t_steps as f64;
    for f in 0..3u64 {
        let l = label as u64;
        let c0 = ((l * 131 + f * 197) % 17) * 40 + 10 + rng.below(8);
        let slope = (((l * 31 + f * 7) % 9) as f64 - 4.0) * 3.0;
        let curve = (((l * 13 + f * 5) % 5) as f64 - 2.0) * 0.18;
        for t in 0..t_steps {
            let tf = t as f64;
            let centre =
                c0 as f64 + slope * tf / t_f * 8.0 + curve * (tf - t_f / 2.0).powi(2) / t_f * 4.0;
            for dc in -6i64..=6 {
                // Python's int() truncates toward zero; `as i64` matches.
                let ch = centre as i64 + dc;
                if (0..INPUTS as i64).contains(&ch) {
                    let p = 0.75 * (-(dc * dc) as f64 / 6.0).exp();
                    if rng.uniform() < p {
                        spikes[t * INPUTS + ch as usize] = 1;
                    }
                }
            }
        }
    }
    Sample { spikes, t_steps, inputs: INPUTS, label }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ridges_are_narrow_bands() {
        let s = sample(0, Split::Train, 12, 13);
        // Per timestep at most 3 ridges × 13 channels are candidates.
        for rc in s.row_counts() {
            assert!(rc <= 39, "row count {rc}");
        }
        assert!(s.nnz() > 0);
    }

    #[test]
    fn class_determines_ridge_positions() {
        // Two samples of the same class share ridge neighbourhoods; c0 values
        // are within the 8-channel jitter of each other.
        let mut by_label: std::collections::HashMap<usize, Vec<u64>> = Default::default();
        for i in 0..60 {
            let s = sample(i, Split::Train, 4, 13);
            by_label.entry(s.label).or_default().push(i);
        }
        let pair = by_label.values().find(|v| v.len() >= 2).expect("repeat class");
        let a = sample(pair[0], Split::Train, 4, 13);
        let b = sample(pair[1], Split::Train, 4, 13);
        let active = |s: &Sample| -> Vec<usize> {
            (0..INPUTS).filter(|&c| (0..4).any(|t| s.spike(t, c) == 1)).collect()
        };
        let (aa, bb) = (active(&a), active(&b));
        // At least one common channel (ridges overlap up to jitter).
        assert!(aa.iter().any(|c| bb.contains(c)));
    }

    #[test]
    fn channels_in_range() {
        for i in 0..20 {
            let s = sample(i, Split::Test, 6, 13);
            assert_eq!(s.spikes.len(), 6 * INPUTS);
        }
    }
}
