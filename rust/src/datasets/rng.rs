//! xorshift64* PRNG — bit-identical to `python/compile/datasets.py`.
//!
//! Both language sides generate the synthetic datasets from this generator
//! so the Rust request path streams *exactly* the test set the model was
//! evaluated on in Python (parity pinned by `golden_datasets.json`).

#[derive(Debug, Clone)]
pub struct XorShift64Star {
    state: u64,
}

impl XorShift64Star {
    /// `seed | 1` guards the all-zero fixed point (as on the Python side).
    pub fn new(seed: u64) -> XorShift64Star {
        XorShift64Star { state: seed | 1 }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = XorShift64Star::new(42);
        let mut b = XorShift64Star::new(42);
        for _ in 0..16 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn matches_python_semantics() {
        // Recompute the first output of seed 12345 by hand (the Python
        // implementation applies the same three shifts then the multiply).
        let mut r = XorShift64Star::new(12345);
        let mut x: u64 = 12345 | 1;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        let expect = x.wrapping_mul(0x2545_F491_4F6C_DD1D);
        assert_eq!(r.next_u64(), expect);
    }

    #[test]
    fn seed_zero_survives() {
        let mut r = XorShift64Star::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = XorShift64Star::new(7);
        let xs: Vec<f64> = (0..1000).map(|_| r.uniform()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((0.4..0.6).contains(&mean), "mean {mean}");
    }

    #[test]
    fn below_in_range() {
        let mut r = XorShift64Star::new(9);
        assert!((0..200).all(|_| r.below(10) < 10));
    }
}
