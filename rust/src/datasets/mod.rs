//! Synthetic spiking datasets — the request-path mirror of
//! `python/compile/datasets.py` (see DESIGN.md §1 for why synthetic sets
//! stand in for Spiking MNIST / DVS Gesture / SHD in this offline build).
//!
//! Every sampler is a pure function of `(index, split, t_steps)` driven by
//! the shared xorshift64* PRNG, so the Rust coordinator streams **the same
//! bits** the Python trainer/evaluator saw — parity is pinned by
//! `artifacts/golden_datasets.json` in the integration tests. (`smnist` is
//! exactly bit-identical; `dvs`/`shd` involve `exp`/`cos` whose last-ulp
//! behaviour may differ between numpy and Rust libm — observed differences
//! are zero in practice, and the golden test allows a microscopic tolerance
//! there.)

pub mod dvs;
pub mod rng;
pub mod shd;
pub mod smnist;

pub use rng::XorShift64Star;

/// Which of the paper's three datasets (§VI-A, Table III).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dataset {
    /// Spiking MNIST stand-in: 16×16 glyphs, 10 classes.
    Smnist,
    /// DVS Gesture stand-in: 20×20 event grid, 11 motion classes.
    Dvs,
    /// SHD stand-in: 700 channels, 20 spectro-temporal classes.
    Shd,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Test,
}

/// One spike-train sample: row-major `[t_steps × inputs]` binary matrix.
#[derive(Debug, Clone)]
pub struct Sample {
    pub spikes: Vec<u8>,
    pub t_steps: usize,
    pub inputs: usize,
    pub label: usize,
}

impl Sample {
    #[inline]
    pub fn spike(&self, t: usize, i: usize) -> u8 {
        self.spikes[t * self.inputs + i]
    }

    pub fn step(&self, t: usize) -> &[u8] {
        &self.spikes[t * self.inputs..(t + 1) * self.inputs]
    }

    /// Encode timestep `t` directly into a bit-packed plane (recycled
    /// buffer — the serving feeder's zero-alloc encoder: no intermediate
    /// `Vec<u8>` is ever cloned onto the stage channels). One-shot callers
    /// can build a fresh plane with
    /// [`SpikePlane::from_bytes`](crate::hdl::SpikePlane::from_bytes)`(sample.step(t))`.
    pub fn step_plane_into(&self, t: usize, plane: &mut crate::hdl::SpikePlane) {
        plane.load_bytes(self.step(t));
    }

    pub fn nnz(&self) -> usize {
        self.spikes.iter().map(|&x| x as usize).sum()
    }

    /// Spikes per timestep (used by golden parity tests).
    pub fn row_counts(&self) -> Vec<usize> {
        (0..self.t_steps)
            .map(|t| self.step(t).iter().map(|&x| x as usize).sum())
            .collect()
    }
}

impl Dataset {
    pub fn parse(s: &str) -> Option<Dataset> {
        match s {
            "smnist" => Some(Dataset::Smnist),
            "dvs" => Some(Dataset::Dvs),
            "shd" => Some(Dataset::Shd),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Dataset::Smnist => "smnist",
            Dataset::Dvs => "dvs",
            Dataset::Shd => "shd",
        }
    }

    pub fn inputs(&self) -> usize {
        match self {
            Dataset::Smnist => 256,
            Dataset::Dvs => 400,
            Dataset::Shd => 700,
        }
    }

    pub fn classes(&self) -> usize {
        match self {
            Dataset::Smnist => 10,
            Dataset::Dvs => 11,
            Dataset::Shd => 20,
        }
    }

    /// The paper's architecture for this dataset (Table XI).
    pub fn paper_arch(&self) -> &'static str {
        match self {
            Dataset::Smnist => "256x128x10",
            Dataset::Dvs => "400x300x300x11",
            Dataset::Shd => "700x256x256x20",
        }
    }

    /// Generate one sample (default seeds match the Python side).
    pub fn sample(&self, index: u64, split: Split, t_steps: usize) -> Sample {
        match self {
            Dataset::Smnist => smnist::sample(index, split, t_steps, 7),
            Dataset::Dvs => dvs::sample(index, split, t_steps, 11),
            Dataset::Shd => shd::sample(index, split, t_steps, 13),
        }
    }

    pub fn all() -> [Dataset; 3] {
        [Dataset::Smnist, Dataset::Dvs, Dataset::Shd]
    }
}

/// Per-sample PRNG construction shared by the three samplers — must mirror
/// `datasets.py`: `base + index * 2_654_435_761` with the split in bit 40.
pub(crate) fn sample_rng(base_tag: u64, seed: u64, index: u64, split: Split) -> XorShift64Star {
    let split_off: u64 = match split {
        Split::Train => 0,
        Split::Test => 1 << 40,
    };
    let base = base_tag
        .wrapping_add(seed.wrapping_mul(1_000_003))
        .wrapping_add(split_off);
    XorShift64Star::new(base.wrapping_add(index.wrapping_mul(2_654_435_761)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samplers_shapes() {
        for ds in Dataset::all() {
            let s = ds.sample(0, Split::Train, 6);
            assert_eq!(s.t_steps, 6);
            assert_eq!(s.inputs, ds.inputs());
            assert_eq!(s.spikes.len(), 6 * ds.inputs());
            assert!(s.label < ds.classes());
            assert!(s.spikes.iter().all(|&x| x <= 1));
        }
    }

    #[test]
    fn deterministic_and_index_sensitive() {
        for ds in Dataset::all() {
            let a = ds.sample(5, Split::Test, 8);
            let b = ds.sample(5, Split::Test, 8);
            let c = ds.sample(6, Split::Test, 8);
            assert_eq!(a.spikes, b.spikes);
            assert_eq!(a.label, b.label);
            assert_ne!(a.spikes, c.spikes);
        }
    }

    #[test]
    fn split_changes_stream() {
        let a = Dataset::Smnist.sample(0, Split::Train, 8);
        let b = Dataset::Smnist.sample(0, Split::Test, 8);
        assert_ne!(a.spikes, b.spikes);
    }

    #[test]
    fn label_coverage() {
        for ds in Dataset::all() {
            let mut seen = std::collections::HashSet::new();
            for i in 0..150 {
                seen.insert(ds.sample(i, Split::Train, 1).label);
            }
            assert_eq!(seen.len(), ds.classes(), "{}", ds.label());
        }
    }

    #[test]
    fn row_counts_sum_to_nnz() {
        let s = Dataset::Shd.sample(3, Split::Train, 10);
        assert_eq!(s.row_counts().iter().sum::<usize>(), s.nnz());
    }

    #[test]
    fn packed_plane_encoding_matches_bytes() {
        let s = Dataset::Smnist.sample(2, Split::Test, 5);
        let mut recycled = crate::hdl::SpikePlane::default();
        let mut total_ones = 0usize;
        for t in 0..s.t_steps {
            s.step_plane_into(t, &mut recycled);
            assert_eq!(recycled, crate::hdl::SpikePlane::from_bytes(s.step(t)), "t={t}");
            assert_eq!(recycled.to_bytes(), s.step(t), "t={t}");
            total_ones += recycled.count_ones();
        }
        assert_eq!(total_ones, s.nnz());
    }

    #[test]
    fn parse_labels() {
        for ds in Dataset::all() {
            assert_eq!(Dataset::parse(ds.label()), Some(ds));
        }
        assert_eq!(Dataset::parse("imagenet"), None);
    }
}
