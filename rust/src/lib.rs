//! # QUANTISENC — software-defined digital quantized spiking neural core
//!
//! A full reproduction of *"A Fully-Configurable Open-Source Software-Defined
//! Digital Quantized Spiking Neural Core Architecture"* (Matinizadeh et al.,
//! 2024) as a three-layer Rust + JAX + Pallas system:
//!
//! * **L1** (build time): Pallas kernel for the quantized LIF layer step
//!   (`python/compile/kernels/lif.py`).
//! * **L2** (build time): JAX SNN model, trainer, and AOT lowering to HLO
//!   text (`python/compile/`).
//! * **L3** (this crate): the request-path system — configuration, the
//!   cycle-accurate digital core simulator, FPGA/ASIC hardware models, the
//!   hardware-software interface with its control-register file, the
//!   pipelined streaming coordinator, and the PJRT runtime that executes the
//!   AOT artifacts. Python never runs on the request path.
//!
//! Module map (see `ARCHITECTURE.md` at the repo root for the layering
//! diagram, the paper-section → module cross-reference, and the serving
//! engine's control-message dataflow; DESIGN.md §4 has the full system
//! inventory):
//!
//! | module        | paper concept |
//! |---------------|---------------|
//! | [`fixed`]     | §III-C signed Qn.q arithmetic (Fig. 6)               |
//! | [`config`]    | Table I static/dynamic configuration, Eq. 9/10       |
//! | [`hdl`]       | Fig. 2 neuron, Fig. 1 layered core, AER, clocking    |
//! | [`hwmodel`]   | FPGA resources/power/timing + ASIC (Tables IV–XII)   |
//! | [`datasets`]  | synthetic spiking datasets (§VI-A substitution)      |
//! | [`coordinator`]| §IV interface, Fig. 8 pipelining, [`coordinator::serving`] engine, [`coordinator::control`] live reconfiguration (§VI-I) |
//! | [`golden`]    | native artifact/golden-vector substrate (no Python)  |
//! | [`runtime`]   | artifact manifest; PJRT executor behind `--features pjrt` |
//! | [`baselines`] | non-pipelined dataflow [30] and Table VII designs    |
//! | [`dse`]       | design-space exploration (Table IX)                  |
//! | [`experiments`]| one generator per paper table/figure                |

pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod datasets;
pub mod dse;
pub mod experiments;
pub mod fixed;
pub mod golden;
pub mod hdl;
pub mod hwmodel;
pub mod runtime;
pub mod util;

/// Canonical repo-relative artifacts directory.
pub fn artifacts_dir() -> std::path::PathBuf {
    // Resolve relative to the crate root so binaries work from any cwd.
    let mut p = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    p.push("artifacts");
    p
}
