//! Signed Qn.q fixed-point arithmetic — paper §III-C, Fig. 6.
//!
//! Bit-identical to `python/compile/fixedpoint.py` (enforced by the
//! `golden_fixedpoint.json` cross-language test vectors). Unlike the Python
//! side, which restricts the emulated datapath to W ≤ 16 (int32 products),
//! this implementation supports the full W ≤ 32 range of the paper
//! (Q17.15 in Table IV) by widening products to i64.
//!
//! Conversion from float **saturates** (one-time software-side weight /
//! register quantization); all datapath ops **wrap** modulo 2^W like the
//! silicon registers. Fixed-point multiply is the Fig.-6 datapath: full
//! 2W-bit product, arithmetic shift right by q (truncation toward −∞ = the
//! paper's *underflow*), wrap to W bits (= the paper's *overflow*).

use std::fmt;

/// Static quantization configuration: n integer bits (sign included) and q
/// fraction bits. `Q5.3` is the paper's 8-bit baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct QSpec {
    n: u8,
    q: u8,
}

/// The paper's evaluated settings (Table IV).
pub const Q1_0: QSpec = QSpec { n: 1, q: 0 }; // "binary"
pub const Q2_2: QSpec = QSpec { n: 2, q: 2 };
pub const Q3_1: QSpec = QSpec { n: 3, q: 1 };
pub const Q5_3: QSpec = QSpec { n: 5, q: 3 };
pub const Q9_7: QSpec = QSpec { n: 9, q: 7 };
pub const Q17_15: QSpec = QSpec { n: 17, q: 15 };

#[derive(Debug, PartialEq)]
pub enum QSpecError {
    Invalid { n: u8, q: u8 },
    Parse(String),
}

impl fmt::Display for QSpecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            QSpecError::Invalid { n, q } => {
                write!(f, "invalid QSpec Q{n}.{q}: need n >= 1, total width <= 32")
            }
            QSpecError::Parse(s) => {
                write!(f, "cannot parse QSpec name {s:?} (expected e.g. \"Q5.3\")")
            }
        }
    }
}

impl std::error::Error for QSpecError {}

impl QSpec {
    pub const fn new_unchecked(n: u8, q: u8) -> QSpec {
        QSpec { n, q }
    }

    pub fn new(n: u8, q: u8) -> Result<QSpec, QSpecError> {
        if n < 1 || (n as u32 + q as u32) > 32 {
            return Err(QSpecError::Invalid { n, q });
        }
        Ok(QSpec { n, q })
    }

    /// Parse `"Q5.3"`-style names (the manifest / CLI format).
    pub fn parse(name: &str) -> Result<QSpec, QSpecError> {
        let body = name
            .strip_prefix('Q')
            .ok_or_else(|| QSpecError::Parse(name.into()))?;
        let (n, q) = body
            .split_once('.')
            .ok_or_else(|| QSpecError::Parse(name.into()))?;
        let n: u8 = n.parse().map_err(|_| QSpecError::Parse(name.into()))?;
        let q: u8 = q.parse().map_err(|_| QSpecError::Parse(name.into()))?;
        QSpec::new(n, q)
    }

    pub const fn n(&self) -> u8 {
        self.n
    }

    pub const fn q(&self) -> u8 {
        self.q
    }

    /// Total width W = n + q in bits (sign included).
    pub const fn width(&self) -> u32 {
        self.n as u32 + self.q as u32
    }

    pub const fn scale(&self) -> i64 {
        1i64 << self.q
    }

    pub const fn max_raw(&self) -> i32 {
        ((1i64 << (self.width() - 1)) - 1) as i32
    }

    pub const fn min_raw(&self) -> i32 {
        (-(1i64 << (self.width() - 1))) as i32
    }

    /// Resolution of one LSB in value units.
    pub fn lsb(&self) -> f64 {
        1.0 / self.scale() as f64
    }

    // --- datapath ops (wrapping, silicon semantics) ------------------------

    /// Wrap an arbitrary integer to W-bit two's complement, sign-extended.
    #[inline]
    pub fn wrap(&self, x: i64) -> i32 {
        let w = self.width();
        if w == 32 {
            return x as i32; // i64 -> i32 truncation IS mod-2^32 wrap
        }
        let half = 1i64 << (w - 1);
        let mask = (1i64 << w) - 1;
        (((x + half) & mask) - half) as i32
    }

    /// Wrapping fixed-point add (integer add rules, Fig. 6 text).
    #[inline]
    pub fn add(&self, a: i32, b: i32) -> i32 {
        self.wrap(a as i64 + b as i64)
    }

    #[inline]
    pub fn sub(&self, a: i32, b: i32) -> i32 {
        self.wrap(a as i64 - b as i64)
    }

    /// Fig.-6 multiply: full 2W-bit product >> q (arithmetic), wrap to W.
    #[inline]
    pub fn mul(&self, a: i32, b: i32) -> i32 {
        self.wrap((a as i64 * b as i64) >> self.q)
    }

    // --- conversions (saturating, software side) ---------------------------

    /// Saturating float → raw. Rounds half away from zero like numpy's
    /// `floor(x*scale + 0.5)` used on the Python side.
    pub fn from_float(&self, x: f64) -> i32 {
        let raw = (x * self.scale() as f64 + 0.5).floor();
        let raw = raw.clamp(self.min_raw() as f64, self.max_raw() as f64);
        raw as i32
    }

    pub fn to_float(&self, raw: i32) -> f64 {
        raw as f64 / self.scale() as f64
    }

    /// True iff `raw` is a representable W-bit value (sign-extended form).
    pub fn in_range(&self, raw: i32) -> bool {
        raw >= self.min_raw() && raw <= self.max_raw()
    }

    pub fn name(&self) -> String {
        format!("Q{}.{}", self.n, self.q)
    }
}

impl fmt::Display for QSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Q{}.{}", self.n, self.q)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn widths_and_ranges() {
        assert_eq!(Q5_3.width(), 8);
        assert_eq!(Q5_3.max_raw(), 127);
        assert_eq!(Q5_3.min_raw(), -128);
        assert_eq!(Q9_7.width(), 16);
        assert_eq!(Q17_15.width(), 32);
        assert_eq!(Q17_15.max_raw(), i32::MAX);
        assert_eq!(Q17_15.min_raw(), i32::MIN);
    }

    #[test]
    fn parse_roundtrip() {
        for qs in [Q2_2, Q3_1, Q5_3, Q9_7, Q17_15] {
            assert_eq!(QSpec::parse(&qs.name()).unwrap(), qs);
        }
        assert!(QSpec::parse("5.3").is_err());
        assert!(QSpec::parse("Q33.0").is_err());
        assert!(QSpec::new(0, 3).is_err());
        assert!(QSpec::new(20, 20).is_err());
    }

    #[test]
    fn wrap_two_complement() {
        assert_eq!(Q5_3.wrap(127), 127);
        assert_eq!(Q5_3.wrap(128), -128);
        assert_eq!(Q5_3.wrap(-129), 127);
        assert_eq!(Q5_3.wrap(256), 0);
        assert_eq!(Q17_15.wrap(i32::MAX as i64 + 1), i32::MIN);
    }

    #[test]
    fn add_mul_basics() {
        // 1.0 + 1.5 = 2.5 (raw 20); 2.0 * 1.5 = 3.0 (raw 24)
        assert_eq!(Q5_3.add(8, 12), 20);
        assert_eq!(Q5_3.mul(16, 12), 24);
        // overflow wraps
        assert_eq!(Q5_3.add(127, 1), -128);
    }

    #[test]
    fn mul_truncates_toward_neg_inf() {
        assert_eq!(Q5_3.mul(1, 1), 0); // +underflow truncates to 0
        assert_eq!(Q5_3.mul(-1, 1), -1); // arithmetic shift floors negative
    }

    #[test]
    fn from_float_saturates_and_rounds() {
        assert_eq!(Q5_3.from_float(1000.0), 127);
        assert_eq!(Q5_3.from_float(-1000.0), -128);
        assert_eq!(Q5_3.from_float(0.0624), 0);
        assert_eq!(Q5_3.from_float(0.0626), 1);
        assert_eq!(Q5_3.to_float(Q5_3.from_float(-0.125)), -0.125);
    }

    #[test]
    fn q17_15_wide_products() {
        // (-2^16) * (-2^16) in raw: product 2^32 >> 15 = 2^17 (in range)
        let a = -(1 << 16);
        assert_eq!(Q17_15.mul(a, a), 1 << 17);
    }

    /// Property (hand-rolled; proptest is unavailable offline): sequential
    /// wrapped adds equal the wrap of the exact sum — ActGen soundness.
    #[test]
    fn prop_add_is_modular_sum() {
        let mut rng = crate::datasets::rng::XorShift64Star::new(0xF00D);
        for qs in [Q2_2, Q5_3, Q9_7, Q17_15] {
            for _ in 0..200 {
                let len = 1 + (rng.below(24) as usize);
                let xs: Vec<i32> = (0..len)
                    .map(|_| qs.wrap(rng.next_u64() as i64))
                    .collect();
                let mut acc = 0i32;
                let mut exact = 0i64;
                for &x in &xs {
                    acc = qs.add(acc, x);
                    exact += x as i64;
                }
                assert_eq!(acc, qs.wrap(exact), "{qs} {xs:?}");
            }
        }
    }

    /// Property: results of all ops stay in the representable range.
    #[test]
    fn prop_ops_in_range() {
        let mut rng = crate::datasets::rng::XorShift64Star::new(0xBEEF);
        for qs in [Q2_2, Q3_1, Q5_3, Q9_7, Q17_15] {
            for _ in 0..300 {
                let a = qs.wrap(rng.next_u64() as i64);
                let b = qs.wrap(rng.next_u64() as i64);
                for r in [qs.add(a, b), qs.sub(a, b), qs.mul(a, b)] {
                    assert!(qs.in_range(r), "{qs}: {a} op {b} -> {r}");
                }
            }
        }
    }

    /// Property: mul matches a big-integer reference on random operands.
    #[test]
    fn prop_mul_matches_wide_reference() {
        let mut rng = crate::datasets::rng::XorShift64Star::new(0xCAFE);
        for qs in [Q5_3, Q9_7, Q17_15] {
            for _ in 0..300 {
                let a = qs.wrap(rng.next_u64() as i64);
                let b = qs.wrap(rng.next_u64() as i64);
                let wide = ((a as i128 * b as i128) >> qs.q()) as i64;
                assert_eq!(qs.mul(a, b), qs.wrap(wide));
            }
        }
    }
}
