//! Table IX — largest wide/deep QUANTISENC configuration per FPGA platform.

use crate::dse;
use crate::fixed::Q5_3;
use crate::hwmodel::Board;
use crate::util::table::Table;

pub fn table9() -> Table {
    let mut t = Table::new(
        "Table IX — largest configuration per FPGA platform (model-driven DSE)",
        &["Platform", "Wide (1 hidden)", "paper", "Power (W)", "Deep (64-wide hiddens)", "paper", "Power (W)"],
    );
    let paper_wide = ["256-1470-10", "256-704-10", "256-640-10"];
    let paper_deep = ["256-28(64)-10", "256-20(64)-10", "256-12(64)-10"];
    for (i, board) in Board::all().iter().enumerate() {
        let wide = dse::largest_wide(board, 256, 10, Q5_3).expect("board fits a minimal design");
        let deep =
            dse::largest_deep(board, 256, 10, 64, Q5_3).expect("board fits a minimal design");
        let h = wide.config.sizes()[1];
        let d = deep.config.num_layers() - 1;
        t.row(vec![
            board.name.into(),
            format!("256-{h}-10"),
            paper_wide[i].into(),
            format!("{:.3}", wide.power_w),
            format!("256-{d}(64)-10"),
            paper_deep[i].into(),
            format!("{:.3}", deep.power_w),
        ]);
    }
    t.note("wide search binds on LUTs and lands within ~5% of the paper on every board; the paper's deep-column limits reflect unmodelled routing/placement pressure — our model binds later, but preserves the cross-platform ordering (Virtex US > Virtex 7 > Zynq US)");
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table9_has_three_platforms() {
        let t = table9();
        assert_eq!(t.rows.len(), 3);
        // Virtex US wide column within 5% of 1470.
        let h: f64 = t.rows[0][1]
            .trim_start_matches("256-")
            .trim_end_matches("-10")
            .parse()
            .unwrap();
        assert!((h - 1470.0).abs() / 1470.0 < 0.05, "H = {h}");
    }

    #[test]
    fn power_ordering_follows_size() {
        let t = table9();
        let p: Vec<f64> = t.rows.iter().map(|r| r[3].parse().unwrap()).collect();
        assert!(p[0] > p[1] && p[1] > p[2], "wide power must track platform size: {p:?}");
    }
}
