//! Table X — run-time (dynamic) configuration sweep: R/C settings, reset
//! mechanisms, refractory periods → average spikes/neuron, accuracy, power.
//!
//! This is the paper's headline configurability claim, measured the way
//! §VI-I describes it: **one** engine is deployed (weights programmed
//! once) and every row is produced by reprogramming *that same live
//! instance* through the control plane — each setting is one cfg_in
//! register program applied via
//! [`crate::coordinator::control::ControlPlane::apply`], with zero core
//! rebuilds across the sweep. Spikes, accuracy, and power all come from
//! the deployed engine's own per-stream activity ledgers, and the cfg_in
//! beats of the sweep are charged to the engine's AXI ledger next to the
//! spike traffic.

use anyhow::Result;

use crate::config::registers::{RegisterFile, ResetMode, REG_REFRACTORY, REG_RESET_MODE};
use crate::coordinator::control::ReconfigProgram;
use crate::coordinator::serving::ServingOptions;
use crate::datasets::Dataset;
use crate::hwmodel::power as pw;
use crate::runtime::artifacts::Manifest;
use crate::util::table::Table;

use super::{engine_from_artifact, evaluate_engine};

pub fn table10(manifest: &Manifest) -> Result<Table> {
    let mut t = Table::new(
        "Table X — impact of dynamic settings (synthetic smnist, live engine re-programmed via the cfg_in control plane)",
        &["setting", "avg spikes/neuron (150-step)", "accuracy", "power (W)", "paper (spk/acc/W)"],
    );
    let art = manifest.model("smnist", "Q5.3")?;
    let n_test = 60u64;

    // One deployment for the whole sweep: weights land once, every row is
    // a cfg_in program on the same live engine.
    let (cfg, mut engine) = engine_from_artifact(&art, ServingOptions::with_cores(2))?;
    let control = engine.control_plane();
    // The deployment registers, read back from the control plane's shadow
    // file — guaranteed to match the engine's epoch-0 configuration.
    let baseline = control.registers();

    // Each row is an *absolute* register program: baseline + one knob, so
    // rows stay independent even though the engine is shared.
    let mut measure = |regs: &RegisterFile| -> Result<(f64, f64, f64)> {
        control.apply(ReconfigProgram::from_registers(regs))?;
        let m = evaluate_engine(&mut engine, Dataset::Smnist, n_test, art.t_steps)?;
        let p = pw::core_dynamic_w(&cfg, m.spike_rate, pw::F0_HZ);
        Ok((m.spikes_per_neuron_150, m.accuracy, p))
    };

    // --- R/C sweep (τ = 5 ms fixed): growth scales with R.
    let rc = [
        (500.0, 10.0, "26 / 96.5% / 0.663"),
        (100.0, 50.0, "19 / 94.4% / 0.541"),
        (50.0, 100.0, "7 / 67.8% / 0.449"),
        (10.0, 500.0, "0 / - / -"),
    ];
    for (r_mohm, c_pf, paper) in rc {
        let mut regs = baseline.clone();
        regs.set_rc(r_mohm, c_pf)?;
        let (spk, acc, p) = measure(&regs)?;
        t.row(vec![
            format!("R={r_mohm:.0}MΩ C={c_pf:.0}pF"),
            format!("{spk:.1}"),
            format!("{:.1}%", 100.0 * acc),
            format!("{p:.3}"),
            paper.into(),
        ]);
    }

    // --- Reset mechanisms (baseline = reset-by-subtraction).
    let resets = [
        (ResetMode::Default, "45 / 92.7% / 1.087"),
        (ResetMode::BySubtraction, "26 / 96.5% / 0.663"),
        (ResetMode::ToZero, "22 / 96.5% / 0.625"),
    ];
    for (mode, paper) in resets {
        let mut regs = baseline.clone();
        regs.write(REG_RESET_MODE, mode as i32)?;
        let (spk, acc, p) = measure(&regs)?;
        t.row(vec![
            format!("reset: {}", mode.label()),
            format!("{spk:.1}"),
            format!("{:.1}%", 100.0 * acc),
            format!("{p:.3}"),
            paper.into(),
        ]);
    }

    // --- Refractory periods 0 and 5.
    for (refr, paper) in [(0, "26 / 96.5% / 0.663"), (5, "20 / 95.8% / 0.580")] {
        let mut regs = baseline.clone();
        regs.write(REG_REFRACTORY, refr)?;
        let (spk, acc, p) = measure(&regs)?;
        t.row(vec![
            format!("refractory = {refr} cycles"),
            format!("{spk:.1}"),
            format!("{:.1}%", 100.0 * acc),
            format!("{p:.3}"),
            paper.into(),
        ]);
    }

    let bus = engine.bus();
    t.note(format!(
        "trends to reproduce: spikes & power fall as R falls (accuracy collapses at small R, zero spikes at 10MΩ); default reset spikes most; refractory trims spikes & power at slight accuracy cost. sweep ran {} config epochs on one live engine (zero rebuilds); cfg_in cost {} bus beats vs {} spk beats on the same AXI ledger",
        engine.epoch(),
        bus.cfg_writes,
        bus.spk_in_events + bus.spk_out_events,
    ));
    Ok(t)
}

// Exercised end-to-end by rust/tests/integration_experiments.rs.
