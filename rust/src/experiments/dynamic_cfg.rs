//! Table X — run-time (dynamic) configuration sweep: R/C settings, reset
//! mechanisms, refractory periods → average spikes/neuron, accuracy, power.
//!
//! This is the paper's headline configurability claim: all of these knobs
//! are programmed through cfg_in *after* deployment, and every number here
//! is measured by re-programming the same deployed core (same weights) and
//! re-running the test set — exactly the §VI-I experiment.

use anyhow::Result;

use crate::config::registers::{ResetMode, REG_REFRACTORY, REG_RESET_MODE};
use crate::datasets::Dataset;
use crate::hwmodel::power as pw;
use crate::runtime::artifacts::Manifest;
use crate::util::table::Table;

use super::{core_from_artifact, evaluate_core};

pub fn table10(manifest: &Manifest) -> Result<Table> {
    let mut t = Table::new(
        "Table X — impact of dynamic settings (synthetic smnist, deployed core re-programmed via cfg_in)",
        &["setting", "avg spikes/neuron (150-step)", "accuracy", "power (W)", "paper (spk/acc/W)"],
    );
    let art = manifest.model("smnist", "Q5.3")?;
    let n_test = 60u64;

    // --- R/C sweep (τ = 5 ms fixed): growth scales with R.
    let rc = [
        (500.0, 10.0, "26 / 96.5% / 0.663"),
        (100.0, 50.0, "19 / 94.4% / 0.541"),
        (50.0, 100.0, "7 / 67.8% / 0.449"),
        (10.0, 500.0, "0 / - / -"),
    ];
    for (r_mohm, c_pf, paper) in rc {
        let (cfg, mut core) = core_from_artifact(&art)?;
        core.registers.set_rc(r_mohm, c_pf)?;
        let m = evaluate_core(&mut core, Dataset::Smnist, n_test, art.t_steps);
        let p = pw::core_dynamic_w(&cfg, m.spike_rate, pw::F0_HZ);
        t.row(vec![
            format!("R={r_mohm:.0}MΩ C={c_pf:.0}pF"),
            format!("{:.1}", m.spikes_per_neuron_150),
            format!("{:.1}%", 100.0 * m.accuracy),
            format!("{p:.3}"),
            paper.into(),
        ]);
    }

    // --- Reset mechanisms (baseline = reset-by-subtraction).
    let resets = [
        (ResetMode::Default, "45 / 92.7% / 1.087"),
        (ResetMode::BySubtraction, "26 / 96.5% / 0.663"),
        (ResetMode::ToZero, "22 / 96.5% / 0.625"),
    ];
    for (mode, paper) in resets {
        let (cfg, mut core) = core_from_artifact(&art)?;
        core.registers.write(REG_RESET_MODE, mode as i32)?;
        let m = evaluate_core(&mut core, Dataset::Smnist, n_test, art.t_steps);
        let p = pw::core_dynamic_w(&cfg, m.spike_rate, pw::F0_HZ);
        t.row(vec![
            format!("reset: {}", mode.label()),
            format!("{:.1}", m.spikes_per_neuron_150),
            format!("{:.1}%", 100.0 * m.accuracy),
            format!("{p:.3}"),
            paper.into(),
        ]);
    }

    // --- Refractory periods 0 and 5.
    for (refr, paper) in [(0, "26 / 96.5% / 0.663"), (5, "20 / 95.8% / 0.580")] {
        let (cfg, mut core) = core_from_artifact(&art)?;
        core.registers.write(REG_REFRACTORY, refr)?;
        let m = evaluate_core(&mut core, Dataset::Smnist, n_test, art.t_steps);
        let p = pw::core_dynamic_w(&cfg, m.spike_rate, pw::F0_HZ);
        t.row(vec![
            format!("refractory = {refr} cycles"),
            format!("{:.1}", m.spikes_per_neuron_150),
            format!("{:.1}%", 100.0 * m.accuracy),
            format!("{p:.3}"),
            paper.into(),
        ]);
    }

    t.note("trends to reproduce: spikes & power fall as R falls (accuracy collapses at small R, zero spikes at 10MΩ); default reset spikes most; refractory trims spikes & power at slight accuracy cost");
    Ok(t)
}

// Exercised end-to-end by rust/tests/integration_experiments.rs.
