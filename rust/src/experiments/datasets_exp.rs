//! Table XI — design summary across the three datasets: utilisation,
//! accuracy, dynamic peak power, peak performance per watt.

use anyhow::Result;

use crate::datasets::Dataset;
use crate::hwmodel::boards::VIRTEX_ULTRASCALE;
use crate::hwmodel::power as pw;
use crate::hwmodel::resources as res;
use crate::runtime::artifacts::Manifest;
use crate::util::table::Table;

use super::{core_from_artifact, evaluate_core};

pub fn table11(manifest: &Manifest) -> Result<Table> {
    let mut t = Table::new(
        "Table XI — design summary per dataset (synthetic stand-ins, Virtex UltraScale)",
        &["Dataset", "Config", "LUT%", "FF%", "BRAM%", "Accuracy", "Power (W)", "GOPS/W @peak",
          "paper (LUT/FF/BRAM/acc/W/GOPS-W)"],
    );
    let rows = [
        (Dataset::Smnist, "9% / 1% / 4% / 96.5% / 0.623 / 36.6"),
        (Dataset::Dvs, "60% / 15% / 18% / 85.07% / 1.827 / 24.45"),
        (Dataset::Shd, "65% / 20% / 24% / 87.8% / 1.629 / 16.09"),
    ];
    for (ds, paper) in rows {
        let art = manifest.model(ds.label(), "Q5.3")?;
        let (cfg, mut core) = core_from_artifact(&art)?;
        let n = match ds {
            Dataset::Smnist => 100,
            _ => 40, // larger nets: keep the sweep fast; trends unaffected
        };
        let m = evaluate_core(&mut core, ds, n, art.t_steps);
        let r = res::core(&cfg);
        let (l, f, b, _) = res::utilisation(&r, &VIRTEX_ULTRASCALE);
        let p = pw::core_dynamic_w(&cfg, m.spike_rate, pw::F0_HZ);
        let (_, ppw) = pw::peak_perf_per_watt(&cfg, m.spike_rate);
        t.row(vec![
            ds.label().into(),
            cfg.arch_name(),
            format!("{:.0}%", 100.0 * l),
            format!("{:.0}%", 100.0 * f),
            format!("{:.0}%", 100.0 * b),
            format!("{:.1}%", 100.0 * m.accuracy),
            format!("{p:.3}"),
            format!("{ppw:.1}"),
            paper.into(),
        ]);
    }
    t.note("shape to reproduce: smnist smallest/most efficient; dvs & shd use most of the fabric, draw more power, and land lower on GOPS/W");
    Ok(t)
}

#[cfg(test)]
mod tests {
    use crate::datasets::Dataset;

    #[test]
    fn paper_arch_strings_parse() {
        use crate::config::ModelConfig;
        use crate::fixed::Q5_3;
        for ds in Dataset::all() {
            let arch = ds.paper_arch().replace('x', "x");
            assert!(ModelConfig::parse_arch(&arch, Q5_3).is_ok(), "{arch}");
        }
    }
}
