//! Experiment generators — one per paper table/figure (DESIGN.md §5).
//!
//! Every generator returns [`crate::util::table::Table`]s that print the
//! same rows/series the paper reports, alongside the paper's published
//! values and our relative error where applicable. The CLI (`repro table
//! <id>` / `repro figure <id>`) and EXPERIMENTS.md are both produced from
//! these functions; `cargo bench` times the underlying workloads.

pub mod accuracy;
pub mod datasets_exp;
pub mod dse_exp;
pub mod dynamic_cfg;
pub mod dynamics;
pub mod resources_exp;
pub mod throughput;

use anyhow::{Context, Result};

use crate::config::registers::RegisterFile;
use crate::config::ModelConfig;
use crate::coordinator::serving::{ServingEngine, ServingOptions};
use crate::datasets::{Dataset, Split};
use crate::fixed::QSpec;
use crate::hdl::{ActivityStats, Core};
use crate::runtime::artifacts::{Manifest, ModelArtifact};
use crate::util::table::Table;

/// Dispatch by experiment id ("4", "5", …, "g" for §VI-G; "3", "4", "10",
/// "12", "13", "14" for figures).
pub fn run_table(id: &str, manifest: Option<&Manifest>) -> Result<Vec<Table>> {
    match id {
        "4" => Ok(vec![resources_exp::table4()]),
        "5" => Ok(vec![resources_exp::table5()]),
        "6" => Ok(vec![resources_exp::table6(manifest.context("table 6 needs artifacts")?)?]),
        "7" => resources_exp::table7(manifest.context("table 7 needs artifacts")?),
        "8" => Ok(vec![accuracy::table8(manifest.context("table 8 needs artifacts")?)?]),
        "9" => Ok(vec![dse_exp::table9()]),
        "10" => Ok(vec![dynamic_cfg::table10(manifest.context("table 10 needs artifacts")?)?]),
        "11" => Ok(vec![datasets_exp::table11(manifest.context("table 11 needs artifacts")?)?]),
        "12" => Ok(vec![resources_exp::table12()]),
        "g" | "G" => Ok(vec![throughput::table_g()]),
        _ => anyhow::bail!("unknown table id {id:?} (have 4..12, g)"),
    }
}

pub fn run_figure(id: &str, manifest: Option<&Manifest>) -> Result<Vec<Table>> {
    match id {
        "3" => Ok(vec![dynamics::fig3()]),
        "4" => Ok(vec![dynamics::fig4()]),
        "10" | "11" => accuracy::fig10_11(manifest.context("figure 10 needs artifacts")?),
        "12" => Ok(vec![accuracy::fig12(manifest.context("figure 12 needs artifacts")?)?]),
        "13" => Ok(throughput::fig13()),
        "14" => Ok(vec![throughput::fig14(manifest)?]),
        _ => anyhow::bail!("unknown figure id {id:?} (have 3, 4, 10, 12, 13, 14)"),
    }
}

/// All experiment ids, in paper order (used by `repro all` and the
/// EXPERIMENTS.md generator).
pub const ALL: &[(&str, &str)] = &[
    ("figure", "3"),
    ("figure", "4"),
    ("table", "4"),
    ("table", "5"),
    ("table", "6"),
    ("table", "7"),
    ("table", "8"),
    ("figure", "10"),
    ("figure", "12"),
    ("table", "g"),
    ("figure", "13"),
    ("figure", "14"),
    ("table", "9"),
    ("table", "10"),
    ("table", "11"),
    ("table", "12"),
];

/// The artifact's deployment target: parsed architecture + the default
/// register file it ships with. Single source of truth for both the
/// single-core ([`core_from_artifact`]) and serving-engine
/// ([`engine_from_artifact`]) deployment paths.
fn artifact_config_regs(art: &ModelArtifact) -> Result<(ModelConfig, RegisterFile)> {
    let arch = art.sizes.iter().map(|s| s.to_string()).collect::<Vec<_>>().join("x");
    let config = ModelConfig::parse_arch(&arch, QSpec::parse(&art.qname)?)?;
    let mut regs = RegisterFile::new(config.qspec);
    for (addr, &v) in art.default_regs.iter().enumerate() {
        regs.write(addr, v)?;
    }
    Ok((config, regs))
}

/// Build a programmed cycle-accurate core from an artifact.
pub fn core_from_artifact(art: &ModelArtifact) -> Result<(ModelConfig, Core)> {
    let (config, regs) = artifact_config_regs(art)?;
    let mut core = Core::new(config.clone());
    core.load_weights(&art.weights)?;
    core.registers = regs;
    Ok((config, core))
}

/// Deploy an artifact as a live [`ServingEngine`] (the §IV "deployed
/// device" in its production form): parse the architecture, program the
/// weights into every shard, and program the artifact's default registers.
/// Returns the config alongside the engine; reconfigure the running engine
/// afterwards through [`ServingEngine::control_plane`].
pub fn engine_from_artifact(
    art: &ModelArtifact,
    options: ServingOptions,
) -> Result<(ModelConfig, ServingEngine)> {
    let (config, regs) = artifact_config_regs(art)?;
    let engine = ServingEngine::new(&config, &art.weights, &regs, options)?;
    Ok((config, engine))
}

/// Measured evaluation of a programmed core over the synthetic test split:
/// accuracy, average per-neuron-per-step spike rate, aggregate activity.
pub struct Measured {
    pub accuracy: f64,
    pub spike_rate: f64,
    /// Spikes per compute neuron per sample, scaled to the paper's 150-step
    /// exposure (Table X's "Avg. Spikes per Neuron" convention).
    pub spikes_per_neuron_150: f64,
    pub stats: ActivityStats,
}

pub fn evaluate_core(core: &mut Core, dataset: Dataset, n: u64, t_steps: usize) -> Measured {
    let mut stats = ActivityStats::default();
    let mut correct = 0u64;
    for i in 0..n {
        let s = dataset.sample(i, Split::Test, t_steps);
        let r = core.run(&s);
        stats.add(&r.stats);
        if r.prediction == s.label {
            correct += 1;
        }
    }
    let spike_rate = stats.spike_rate();
    Measured {
        accuracy: correct as f64 / n.max(1) as f64,
        spike_rate,
        spikes_per_neuron_150: spike_rate * 150.0,
        stats,
    }
}

/// As [`evaluate_core`], but through a live [`ServingEngine`]: the batch is
/// served by the deployed engine and accuracy/activity are read from the
/// engine's own results (each [`crate::coordinator::serving::StreamResult`]
/// carries the full per-stream activity ledger), so spikes, accuracy, and
/// the power derived from the spike rate all come from the *same deployed
/// instance* — the §VI-I methodology.
pub fn evaluate_engine(
    engine: &mut ServingEngine,
    dataset: Dataset,
    n: u64,
    t_steps: usize,
) -> Result<Measured> {
    let samples: Vec<_> = (0..n).map(|i| dataset.sample(i, Split::Test, t_steps)).collect();
    let results = engine.run_batch(&samples)?;
    let mut stats = ActivityStats::default();
    let mut correct = 0u64;
    for (r, s) in results.iter().zip(&samples) {
        stats.add(&r.stats);
        if r.prediction == s.label {
            correct += 1;
        }
    }
    let spike_rate = stats.spike_rate();
    Ok(Measured {
        accuracy: correct as f64 / n.max(1) as f64,
        spike_rate,
        spikes_per_neuron_150: spike_rate * 150.0,
        stats,
    })
}
