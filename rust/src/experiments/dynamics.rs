//! Figures 3 & 4 — single-neuron membrane dynamics under a 40 ms step
//! input (τ = 5 ms, Vth = 10 mV), regenerated from the cycle-accurate
//! neuron via [`crate::hdl::neuron::DynamicsProbe`].

use crate::config::registers::{RegisterFile, ResetMode};
use crate::fixed::Q9_7;
use crate::hdl::neuron::DynamicsProbe;
use crate::util::table::Table;

/// ASCII sparkline of a membrane trace (the "figure").
fn sparkline(vals: &[f64], vth: f64) -> String {
    let max = vals.iter().cloned().fold(vth, f64::max).max(1e-9);
    vals.iter()
        .map(|&v| {
            let lvls = [' ', '.', ':', '-', '=', '+', '*', '#'];
            let idx = ((v / max).clamp(0.0, 1.0) * (lvls.len() - 1) as f64) as usize;
            lvls[idx]
        })
        .collect()
}

/// Fig. 3: impact of R and C on membrane dynamics. τ = RC fixed at 5 ms;
/// the drive current is chosen so R·I = 10.5·(R/500MΩ)·50 mV — i.e. only
/// the largest-R settings cross the 10 mV threshold, like the paper.
pub fn fig3() -> Table {
    let mut t = Table::new(
        "Figure 3 — R/C settings vs membrane dynamics (step input, 40 ms, τ=5 ms, Vth=10 mV)",
        &["R (MΩ)", "C (pF)", "growth", "spikes", "paper trend", "vmem trace (40 steps)"],
    );
    let settings = [
        (500.0, 10.0, "many spikes"),
        (100.0, 50.0, "fewer spikes"),
        (50.0, 100.0, "few spikes"),
        (10.0, 500.0, "no spikes"),
    ];
    let mut counts = Vec::new();
    for (r_mohm, c_pf, trend) in settings {
        let mut regs = RegisterFile::new(Q9_7);
        regs.set_vth(10.0).unwrap();
        regs.set_rc(r_mohm, c_pf).unwrap();
        regs.set_reset_mode(ResetMode::BySubtraction).unwrap();
        let growth = Q9_7.to_float(regs.growth());
        let probe = DynamicsProbe::new(Q9_7, regs);
        let trace = probe.step_input(20.0, 40);
        counts.push(trace.spike_count());
        t.row(vec![
            format!("{r_mohm:.0}"),
            format!("{c_pf:.0}"),
            format!("{growth:.3}"),
            trace.spike_count().to_string(),
            trend.into(),
            sparkline(&trace.vmem, 10.0),
        ]);
    }
    t.note(format!(
        "spike ordering {:?} reproduces the paper's monotone R/C trend; R=10MΩ never crosses Vth",
        counts
    ));
    t
}

/// Fig. 4: reset mechanisms (default exponential decay, reset-by-
/// subtraction, reset-to-zero) under the same step input. Paper counts:
/// 37 (default) > 14 (subtract) > fewest (zero).
pub fn fig4() -> Table {
    let mut t = Table::new(
        "Figure 4 — reset mechanisms vs neuron dynamics (step input, 40 ms)",
        &["reset mechanism", "spikes (ours)", "paper", "vmem trace"],
    );
    let cases = [
        (ResetMode::Default, "37"),
        (ResetMode::BySubtraction, "14"),
        (ResetMode::ToZero, "fewest"),
    ];
    let mut counts = Vec::new();
    for (mode, paper) in cases {
        let mut regs = RegisterFile::new(Q9_7);
        regs.set_vth(10.0).unwrap();
        regs.set_growth(1.0).unwrap();
        regs.set_reset_mode(mode).unwrap();
        let probe = DynamicsProbe::new(Q9_7, regs);
        let trace = probe.step_input(20.0, 40);
        counts.push(trace.spike_count());
        t.row(vec![
            mode.label().into(),
            trace.spike_count().to_string(),
            paper.into(),
            sparkline(&trace.vmem, 10.0),
        ]);
    }
    t.note(format!(
        "ordering default({}) ≥ subtract({}) ≥ zero({}) matches Fig. 4",
        counts[0], counts[1], counts[2]
    ));
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_rows_and_ordering() {
        let t = fig3();
        assert_eq!(t.rows.len(), 4);
        let spikes: Vec<usize> = t.rows.iter().map(|r| r[3].parse().unwrap()).collect();
        assert!(spikes[0] > spikes[1] && spikes[1] > spikes[2] && spikes[2] >= spikes[3]);
        assert_eq!(spikes[3], 0);
    }

    #[test]
    fn fig4_rows_and_ordering() {
        let t = fig4();
        let spikes: Vec<usize> = t
            .rows
            .iter()
            .map(|r| r[1].parse().unwrap())
            .collect();
        assert!(spikes[0] >= spikes[1] && spikes[1] >= spikes[2]);
        assert!(spikes[2] > 0);
    }

    #[test]
    fn sparkline_scales() {
        let s = sparkline(&[0.0, 5.0, 10.0], 10.0);
        assert_eq!(s.len(), 3);
        assert!(s.ends_with('#'));
    }
}
