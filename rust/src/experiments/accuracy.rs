//! Table VIII (software vs hardware accuracy per quantization), Fig. 10/11
//! (classification example + spike-counter readout), Fig. 12 (quantization
//! impact on the membrane trace, RMSE vs the float software reference).

use anyhow::Result;

use crate::datasets::{Dataset, Split};
use crate::fixed::QSpec;
use crate::runtime::artifacts::{self, Manifest};
use crate::util::stats;
use crate::util::table::Table;

use super::{core_from_artifact, evaluate_core};

/// Table VIII: SNNTorch(float) vs hardware accuracy at Q9.7 / Q5.3 / Q3.1.
pub fn table8(manifest: &Manifest) -> Result<Table> {
    let mut t = Table::new(
        "Table VIII — accuracy per quantization (synthetic smnist, 100 test samples)",
        &["Dataset", "Software (float)", "Q9.7", "Q5.3", "Q3.1", "paper (SW/Q9.7/Q5.3/Q3.1)"],
    );
    let mut accs = Vec::new();
    let mut float_acc = 0.0;
    for q in ["Q9.7", "Q5.3", "Q3.1"] {
        let art = manifest.model("smnist", q)?;
        float_acc = art.float_acc;
        let (_, mut core) = core_from_artifact(&art)?;
        let m = evaluate_core(&mut core, Dataset::Smnist, 100, art.t_steps);
        accs.push(m.accuracy);
    }
    t.row(vec![
        "Spiking MNIST (synthetic)".into(),
        format!("{:.1}%", 100.0 * float_acc),
        format!("{:.1}%", 100.0 * accs[0]),
        format!("{:.1}%", 100.0 * accs[1]),
        format!("{:.1}%", 100.0 * accs[2]),
        "97.8% / 97.1% / 96.5% / 88.3%".into(),
    ]);
    t.note("trend to reproduce: accuracy degrades as precision shrinks, Q9.7 ≈ software");
    Ok(t)
}

/// Fig. 10 + 11: one classification example — per-layer spike raster
/// summary and the output spike-counter histogram.
pub fn fig10_11(manifest: &Manifest) -> Result<Vec<Table>> {
    let art = manifest.model("smnist", "Q5.3")?;
    let (_, mut core) = core_from_artifact(&art)?;

    // Find a test sample whose label is 8 (the paper's example digit).
    let mut idx = 0;
    let sample = loop {
        let s = Dataset::Smnist.sample(idx, Split::Test, art.t_steps);
        if s.label == 8 {
            break s;
        }
        idx += 1;
        if idx > 500 {
            anyhow::bail!("no digit-8 sample found");
        }
    };
    let r = core.run(&sample);

    let mut t1 = Table::new(
        format!("Figure 10 — spike raster summary (digit {} example, sample {idx})", sample.label),
        &["layer", "size", "total spikes", "spikes/step"],
    );
    t1.row(vec![
        "input".into(),
        sample.inputs.to_string(),
        sample.nnz().to_string(),
        format!("{:.1}", sample.nnz() as f64 / sample.t_steps as f64),
    ]);
    for (k, &spk) in r.layer_spikes.iter().enumerate() {
        t1.row(vec![
            format!("layer {}", k + 1),
            art.sizes[k + 1].to_string(),
            spk.to_string(),
            format!("{:.1}", spk as f64 / sample.t_steps as f64),
        ]);
    }

    let mut t2 = Table::new(
        "Figure 11 — output spike counter (classification readout)",
        &["output neuron", "spike count", "bar"],
    );
    let max = r.counts.iter().copied().max().unwrap_or(1).max(1);
    for (i, &c) in r.counts.iter().enumerate() {
        let bar = "#".repeat((c as usize * 40) / max as usize);
        let mark = if i == r.prediction { " <= prediction" } else { "" };
        t2.row(vec![i.to_string(), c.to_string(), format!("{bar}{mark}")]);
    }
    t2.note(format!(
        "predicted {} (true label {}); paper: neuron 8 highest, neuron 3 and 0 next (shared glyph segments)",
        r.prediction, sample.label
    ));
    Ok(vec![t1, t2])
}

/// Fig. 12: membrane trace of a hidden-layer neuron per quantization vs the
/// double-precision software trace; average RMSE over test samples.
pub fn fig12(manifest: &Manifest) -> Result<Table> {
    let mut t = Table::new(
        "Figure 12 — quantization impact on membrane potential (hidden layer, RMSE vs float)",
        &["Q", "avg RMSE (value units)", "paper (mV)", "samples", "neurons"],
    );
    // Float reference: software LIF on the float weights.
    let art53 = manifest.model("smnist", "Q5.3")?;
    let float_w = artifacts::load_float_weight_file(
        &manifest.root.join("weights_smnist_float.bin"),
        &art53.layer_shapes,
    )?;

    let n_samples = 20u64;
    for (q, paper) in [("Q9.7", "0.25"), ("Q5.3", "0.43"), ("Q3.1", "2.12")] {
        let art = manifest.model("smnist", q)?;
        let qs = QSpec::parse(q)?;
        let (_, mut core) = core_from_artifact(&art)?;
        // Deployment pre-scaling: hardware runs at vth = s·1.0, so divide
        // its trace by s to compare with the unit-threshold float model.
        let scale = qs.to_float(art.default_regs[crate::config::registers::REG_VTH]);
        let mut rmses = Vec::new();
        for i in 0..n_samples {
            let sample = Dataset::Smnist.sample(i, Split::Test, art.t_steps);
            // Hardware trace: hidden-layer vmem per step (value units).
            let mut hw_trace: Vec<f64> = Vec::new();
            core.reset();
            let mut layer_spikes = vec![0u64; art.layer_shapes.len()];
            for tstep in 0..sample.t_steps {
                core.step(sample.step(tstep), &mut layer_spikes);
                for &v in core.layers()[0].vmem_slice() {
                    hw_trace.push(qs.to_float(v) / scale);
                }
            }
            // Software trace: float LIF with the same topology.
            let sw_trace = float_hidden_trace(&float_w, &sample);
            rmses.push(stats::rmse(&hw_trace, &sw_trace));
        }
        t.row(vec![
            q.into(),
            format!("{:.4}", stats::mean(&rmses)),
            paper.into(),
            n_samples.to_string(),
            art.sizes[1].to_string(),
        ]);
    }
    t.note("ordering RMSE(Q9.7) < RMSE(Q5.3) < RMSE(Q3.1) is the Fig. 12 claim; absolute units differ (our Vth=1.0 scale vs the paper's mV)");
    Ok(t)
}

/// Double-precision software LIF (reset-by-subtraction), hidden-layer trace —
/// the Rust mirror of `model.float_membrane_trace`.
fn float_hidden_trace(weights: &[Vec<f32>], sample: &crate::datasets::Sample) -> Vec<f64> {
    let (m, n) = (sample.inputs, weights[0].len() / sample.inputs);
    let (decay, growth, vth) = (0.2f64, 1.0f64, 1.0f64);
    let mut vmem = vec![0.0f64; n];
    let mut out = Vec::with_capacity(sample.t_steps * n);
    for t in 0..sample.t_steps {
        let spk = sample.step(t);
        for j in 0..n {
            let mut act = 0.0f64;
            for i in 0..m {
                if spk[i] != 0 {
                    act += weights[0][i * n + j] as f64;
                }
            }
            let mut v = vmem[j] - decay * vmem[j] + growth * act;
            if v >= vth {
                v -= vth;
            }
            vmem[j] = v;
            out.push(v);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    // Artifact-dependent generators are exercised by the integration tests
    // (rust/tests/integration_experiments.rs) and the CLI; the pure helper
    // is tested here.
    use super::*;

    #[test]
    fn float_trace_shape() {
        let sample = crate::datasets::Sample {
            spikes: vec![1, 0, 1, 0, 0, 1],
            t_steps: 2,
            inputs: 3,
            label: 0,
        };
        let w = vec![vec![0.5f32; 3 * 4]];
        let tr = float_hidden_trace(&w, &sample);
        assert_eq!(tr.len(), 2 * 4);
        assert!(tr.iter().all(|v| v.is_finite()));
    }
}
