//! Tables IV, V, VI, VII, XII — resource/power characterisation tables.

use anyhow::Result;

use crate::baselines;
use crate::config::{MemKind, ModelConfig, Topology};
use crate::fixed::{QSpec, Q17_15, Q1_0, Q2_2, Q5_3, Q9_7};
use crate::hwmodel::boards::VIRTEX_ULTRASCALE;
use crate::hwmodel::power as pw;
use crate::hwmodel::resources as res;
use crate::hwmodel::asic;
use crate::runtime::artifacts::Manifest;
use crate::util::stats::rel_err;
use crate::util::table::Table;

use super::{core_from_artifact, evaluate_core};
use crate::datasets::Dataset;

fn err_cell(ours: f64, paper: f64) -> String {
    format!("{:.1}%", 100.0 * rel_err(ours, paper))
}

/// Table IV: LIF resources + power vs quantization.
pub fn table4() -> Table {
    let mut t = Table::new(
        "Table IV — LIF resource utilisation vs quantization (single neuron, 100 MHz)",
        &["Quantization", "LUTs", "paper", "FFs", "paper", "DSPs", "paper", "Power (mW)", "paper"],
    );
    let rows: [(&str, QSpec, f64, f64, f64, f64); 5] = [
        ("binary", Q1_0, 14.0, 11.0, 0.0, 3.0),
        ("4 bits (Q2.2)", Q2_2, 66.0, 19.0, 0.0, 4.0),
        ("8 bits (Q5.3)", Q5_3, 245.0, 35.0, 0.0, 6.0),
        ("16 bits (Q9.7)", Q9_7, 242.0, 68.0, 2.0, 14.0),
        ("32 bits (Q17.15)", Q17_15, 856.0, 132.0, 8.0, 27.0),
    ];
    for (name, qs, p_lut, p_ff, p_dsp, p_pow) in rows {
        let r = res::lif_neuron(qs);
        let p = res::lif_neuron_power_mw(qs);
        t.row(vec![
            name.into(),
            format!("{:.0}", r.luts),
            format!("{p_lut:.0}"),
            format!("{:.0}", r.ffs),
            format!("{p_ff:.0}"),
            format!("{:.0}", r.dsps),
            format!("{p_dsp:.0}"),
            format!("{p:.0}"),
            format!("{p_pow:.0}"),
        ]);
    }
    t.note("model anchored at the paper's five published points (calibration = validation here; interpolation covers unevaluated widths)");
    t
}

/// Table V: resources/power per connection modality (Q5.3).
pub fn table5() -> Table {
    let mut t = Table::new(
        "Table V — resources & peak dynamic power per connection modality (Q5.3)",
        &["Connections", "LUTs", "err", "FFs", "err", "BRAMs", "Power (mW)", "err"],
    );
    let rows: [(&str, Topology, usize, f64, f64, f64, f64); 6] = [
        ("one-to-one", Topology::OneToOne, 1, 296.0, 56.0, 0.0, 12.0),
        ("conv 3x3", Topology::Gaussian { radius: 1 }, 20, 284.0, 80.0, 0.5, 17.0),
        ("conv 5x5", Topology::Gaussian { radius: 2 }, 20, 300.0, 130.0, 0.5, 18.0),
        ("FC 128", Topology::AllToAll, 128, 420.0, 443.0, 0.5, 23.0),
        ("FC 256", Topology::AllToAll, 256, 551.0, 829.0, 0.5, 29.0),
        ("FC 512", Topology::AllToAll, 512, 822.0, 1599.0, 0.5, 48.0),
    ];
    for (name, topo, fan_in, p_lut, p_ff, p_bram, p_pow) in rows {
        let r = res::connection_block(topo, fan_in, MemKind::Bram);
        let p = pw::connection_block_power_mw(topo, fan_in);
        t.row(vec![
            name.into(),
            format!("{:.0}", r.luts),
            err_cell(r.luts, p_lut),
            format!("{:.0}", r.ffs),
            err_cell(r.ffs, p_ff),
            format!("{:.1}", r.brams),
            format!("{p:.0}"),
            err_cell(p, p_pow),
        ]);
        let _ = p_bram;
    }
    t.note("affine fits in fan-in / tap count; per-cell error vs the paper shown inline");
    t
}

/// Table VI: full-architecture scaling, with *measured* spike activity from
/// the cycle-accurate core driving the power model.
pub fn table6(manifest: &Manifest) -> Result<Table> {
    let mut t = Table::new(
        "Table VI — resource utilisation & dynamic power per SNN architecture (Virtex UltraScale)",
        &["Config", "Q", "Neurons", "Synapses", "LUT%", "paper", "FF%", "paper", "BRAM%", "paper",
          "DSP%", "Power (W)", "paper"],
    );
    // Measured baseline activity: run the real smnist artifact weights.
    let art = manifest.model("smnist", "Q5.3")?;
    let (_, mut core) = core_from_artifact(&art)?;
    let measured = evaluate_core(&mut core, Dataset::Smnist, 40, art.t_steps);
    let rate = measured.spike_rate;

    let rows: [(&str, QSpec, f64, f64, f64, f64, f64); 4] = [
        ("256x128x10", Q5_3, 8.97, 0.98, 3.99, 0.0, 0.623),
        ("256x128x10", Q9_7, 9.38, 1.39, 3.99, 35.93, 0.738),
        ("256x256x10", Q5_3, 17.44, 1.85, 7.69, 0.0, 1.241),
        ("256x256x256x10", Q5_3, 34.08, 3.55, 15.10, 0.0, 2.172),
    ];
    for (arch, qs, p_lut, p_ff, p_bram, p_dsp, p_pow) in rows {
        let cfg = ModelConfig::parse_arch(arch, qs)?;
        let r = res::core(&cfg);
        let (l, f, b, d) = res::utilisation(&r, &VIRTEX_ULTRASCALE);
        // Larger nets keep roughly the baseline per-neuron rate (the paper's
        // power column scales with synapses at fixed activity).
        let p = pw::core_dynamic_w(&cfg, rate, pw::F0_HZ);
        t.row(vec![
            arch.into(),
            qs.name(),
            cfg.total_neurons().to_string(),
            cfg.total_synapses().to_string(),
            format!("{:.2}%", 100.0 * l),
            format!("{p_lut:.2}%"),
            format!("{:.2}%", 100.0 * f),
            format!("{p_ff:.2}%"),
            format!("{:.2}%", 100.0 * b),
            format!("{p_bram:.2}%"),
            format!("{:.2}%", 100.0 * d),
            format!("{p:.3}"),
            format!("{p_pow:.3}"),
        ]);
        let _ = p_dsp;
    }
    t.note(format!(
        "power driven by measured smnist activity: {:.3} spikes/neuron/step ({:.0} per 150-step exposure)",
        rate,
        rate * 150.0
    ));
    Ok(t)
}

/// Table VII: comparison against state-of-the-art designs.
pub fn table7(manifest: &Manifest) -> Result<Vec<Table>> {
    let mut t1 = Table::new(
        "Table VII (left) — single neuron vs Euler designs",
        &["Design", "LUTs", "FFs", "BRAMs", "Power (W)"],
    );
    for d in [baselines::EULER_GUO_33, baselines::EULER_YE_34] {
        t1.row(vec![
            d.citation.into(),
            d.luts.to_string(),
            d.ffs.to_string(),
            d.brams.to_string(),
            d.power_w.map(|p| format!("{p}")).unwrap_or_else(|| "NR".into()),
        ]);
    }
    // "Ours": the paper's single neuron is Q5.3 with runtime configurability.
    let ours = baselines::PAPER_OURS_NEURON;
    let model = res::lif_neuron(Q5_3);
    t1.row(vec![
        format!("Ours (paper: {} LUTs)", ours.luts),
        format!("{:.0}", model.luts),
        format!("{:.0}", model.ffs),
        "0".into(),
        format!("{}", ours.power_w.unwrap()),
    ]);
    t1.note("our neuron spends extra logic on run-time configurability (refractory, reset, rates, Vth) — the paper's key distinction vs [33]/[34]");

    let mut t2 = Table::new(
        "Table VII (right) — full SNN architectures on Spiking MNIST",
        &["Design", "Config", "Neurons", "Synapses", "LUTs", "FFs", "BRAMs", "Power (W)", "Accuracy"],
    );
    for d in [baselines::BEST_ACCURACY_28, baselines::BEST_HARDWARE_35] {
        t2.row(vec![
            d.citation.into(),
            d.config.into(),
            d.neurons.unwrap().to_string(),
            d.synapses.unwrap().to_string(),
            d.luts.to_string(),
            d.ffs.to_string(),
            d.brams.to_string(),
            format!("{}", d.power_w.unwrap()),
            format!("{:.1}%", 100.0 * d.accuracy.unwrap()),
        ]);
    }
    let art = manifest.model("smnist", "Q5.3")?;
    let (cfg, mut core) = core_from_artifact(&art)?;
    let m = evaluate_core(&mut core, Dataset::Smnist, 100, art.t_steps);
    let r = res::core(&cfg);
    let p = pw::core_dynamic_w(&cfg, m.spike_rate, pw::F0_HZ);
    t2.row(vec![
        "Ours (measured/model)".into(),
        cfg.arch_name(),
        cfg.total_neurons().to_string(),
        cfg.total_synapses().to_string(),
        format!("{:.0}", r.luts),
        format!("{:.0}", r.ffs),
        format!("{:.0}", r.brams),
        format!("{p:.3}"),
        format!("{:.1}%", 100.0 * m.accuracy),
    ]);
    t2.note("paper's own row: 40,965 LUTs / 7,095 FFs / 69 BRAMs / 0.623 W / 96.5% — fewer neurons+synapses than [28]/[35] at comparable accuracy and lowest power");
    Ok(vec![t1, t2])
}

/// Table XII: early ASIC synthesis of the Q5.3 LIF neuron.
pub fn table12() -> Table {
    let mut t = Table::new(
        "Table XII — early ASIC synthesis (Synopsys-DC-calibrated model, 32 nm, 100 MHz)",
        &["Q", "Nets", "Comb", "Seq", "Buf/Inv", "Area (µm²)", "Switch (µW)", "Leak (µW)", "Total (µW)"],
    );
    for qs in [Q5_3, Q9_7, Q2_2] {
        let s = asic::synthesize_lif(qs, 100e6);
        t.row(vec![
            qs.name(),
            format!("{:.0}", s.nets),
            format!("{:.0}", s.comb_cells),
            format!("{:.0}", s.seq_cells),
            format!("{:.0}", s.buf_inv),
            format!("{:.0}", s.area_um2),
            format!("{:.1}", s.switching_power_uw),
            format!("{:.1}", s.leakage_power_uw),
            format!("{:.1}", s.total_power_uw()),
        ]);
    }
    t.note("Q5.3 row reproduces the paper's anchor exactly (1574/944/35/309, 2894 µm², 23.2+78.5 µW); other widths are model extrapolations");
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_shape() {
        let t = table4();
        assert_eq!(t.rows.len(), 5);
        assert_eq!(t.rows[2][1], "245"); // Q5.3 LUTs anchor
    }

    #[test]
    fn table5_errors_small() {
        let t = table5();
        for row in &t.rows {
            let err: f64 = row[2].trim_end_matches('%').parse().unwrap();
            assert!(err < 3.0, "{row:?}");
        }
    }

    #[test]
    fn table12_anchor() {
        let t = table12();
        assert_eq!(t.rows[0][1], "1574");
        assert_eq!(t.rows[0][8], "101.7");
    }
}
