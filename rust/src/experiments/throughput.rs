//! §VI-G (pipelined throughput), Fig. 13 (timing/power vs frequency per
//! memory fabric), Fig. 14 (performance per watt vs frequency).

use anyhow::Result;

use crate::baselines::DataflowBaseline;
use crate::config::{MemKind, ModelConfig};
use crate::coordinator::pipeline::ScheduleModel;
use crate::fixed::Q5_3;
use crate::hwmodel::power as pw;
use crate::hwmodel::timing;
use crate::runtime::artifacts::Manifest;
use crate::util::table::Table;

use super::{core_from_artifact, evaluate_core};
use crate::datasets::Dataset;

/// §VI-G: real-time performance, pipelined vs the [30] dataflow baseline.
pub fn table_g() -> Table {
    let mut t = Table::new(
        "§VI-G — real-time performance: pipelined vs non-pipelined dataflow [30]",
        &["schedule", "fps (ours)", "fps (paper)", "formula"],
    );
    let m = ScheduleModel::paper_baseline();
    let cfg = ModelConfig::parse_arch("256x128x10", Q5_3).unwrap();
    let baseline = DataflowBaseline::new(cfg);
    t.row(vec![
        "pipelined (Fig. 8)".into(),
        format!("{:.2}", m.pipelined_fps()),
        "41.67".into(),
        "1/(exposure + N_reset/f)".into(),
    ]);
    t.row(vec![
        "dataflow [30]".into(),
        format!("{:.2}", baseline.fps(m.exposure_s, m.f_hz)),
        "31.25".into(),
        "1/(exposure + K*L/f)".into(),
    ]);
    t.note(format!(
        "pipelining improvement: {:.1}% (paper: 33.3%); initiation interval {:.3} s, fill latency {:.3} s",
        100.0 * (m.speedup() - 1.0),
        m.initiation_interval_s(),
        m.fill_latency_s()
    ));
    t
}

/// Fig. 13: worst setup slack + dynamic power vs spike frequency for the
/// three synaptic-memory fabrics.
pub fn fig13() -> Vec<Table> {
    let mut t1 = Table::new(
        "Figure 13 — worst setup slack (ns) vs spike frequency per memory fabric",
        &["f (kHz)", "BRAM", "distributed LUT", "register", "violations"],
    );
    for f in timing::fig13_grid_hz() {
        let slacks: Vec<f64> =
            MemKind::all().iter().map(|&m| timing::setup_slack_ns(m, f)).collect();
        let viol: Vec<&str> = MemKind::all()
            .iter()
            .zip(&slacks)
            .filter(|(_, &s)| s < 0.0)
            .map(|(m, _)| m.label())
            .collect();
        t1.row(vec![
            format!("{:.0}", f / 1e3),
            format!("{:.0}", slacks[0]),
            format!("{:.0}", slacks[1]),
            format!("{:.0}", slacks[2]),
            if viol.is_empty() { "-".into() } else { viol.join(",") },
        ]);
    }
    t1.note(format!(
        "peak frequencies: BRAM {:.0} kHz, LUT {:.0} kHz, register {:.0} kHz (paper: 925 / 850 / 500)",
        timing::peak_frequency_hz(MemKind::Bram) / 1e3,
        timing::peak_frequency_hz(MemKind::DistributedLut) / 1e3,
        timing::peak_frequency_hz(MemKind::Register) / 1e3,
    ));

    let mut t2 = Table::new(
        "Figure 13 (subplot) — dynamic power (W) vs frequency per memory fabric (256x128x10)",
        &["f (kHz)", "BRAM", "distributed LUT", "register"],
    );
    let cfg = ModelConfig::parse_arch("256x128x10", Q5_3).unwrap();
    for f in timing::fig13_grid_hz() {
        let p = |mem: MemKind| {
            pw::core_dynamic_w(&cfg.clone().with_mem(mem), pw::RATE0, f)
        };
        t2.row(vec![
            format!("{:.0}", f / 1e3),
            format!("{:.3}", p(MemKind::Bram)),
            format!("{:.3}", p(MemKind::DistributedLut)),
            format!("{:.3}", p(MemKind::Register)),
        ]);
    }
    t2.note("distributed LUT lowest at every frequency: 23% below BRAM, 79% below register (paper §VI-G)");
    vec![t1, t2]
}

/// Fig. 14: performance per watt vs frequency for the three Table VI
/// architectures (BRAM memory), with the peak marked.
pub fn fig14(manifest: Option<&Manifest>) -> Result<Table> {
    let mut t = Table::new(
        "Figure 14 — performance per watt (GOPS/W) vs spike frequency (BRAM)",
        &["f (kHz)", "256x128x10", "256x256x10", "256x256x256x10"],
    );
    // Use measured activity when artifacts are available, else the paper rate.
    let rate = match manifest {
        Some(m) => {
            let art = m.model("smnist", "Q5.3")?;
            let (_, mut core) = core_from_artifact(&art)?;
            evaluate_core(&mut core, Dataset::Smnist, 25, art.t_steps).spike_rate
        }
        None => pw::RATE0,
    };
    let archs = ["256x128x10", "256x256x10", "256x256x256x10"];
    let cfgs: Vec<ModelConfig> =
        archs.iter().map(|a| ModelConfig::parse_arch(a, Q5_3).unwrap()).collect();
    let mut f = 100e3;
    while f <= 1000e3 {
        t.row(
            std::iter::once(format!("{:.0}", f / 1e3))
                .chain(cfgs.iter().map(|c| format!("{:.1}", pw::perf_per_watt(c, rate, f))))
                .collect(),
        );
        f += 100e3;
    }
    let peaks: Vec<String> = cfgs
        .iter()
        .map(|c| {
            let (fp, ppw) = pw::peak_perf_per_watt(c, rate);
            format!("{} peaks {:.1} GOPS/W @ {:.0} kHz", c.arch_name(), ppw, fp / 1e3)
        })
        .collect();
    t.note(peaks.join("; "));
    t.note("paper: perf/W rises, peaks below the max supported frequency, then falls; baseline peak 36.6 GOPS/W");
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_g_matches_paper() {
        let t = table_g();
        assert!(t.rows[0][1].starts_with("41.67"));
        assert!(t.rows[1][1].starts_with("31.25"));
    }

    #[test]
    fn fig13_has_violation_markers() {
        let tables = fig13();
        let last = tables[0].rows.last().unwrap();
        assert!(last[4].contains("register"), "register must violate at 1.2 MHz: {last:?}");
    }

    #[test]
    fn fig14_runs_without_artifacts() {
        let t = fig14(None).unwrap();
        assert_eq!(t.rows.len(), 10);
        // perf/W at 600 kHz higher than at 100 kHz for the baseline
        let p100: f64 = t.rows[0][1].parse().unwrap();
        let p600: f64 = t.rows[5][1].parse().unwrap();
        assert!(p600 > p100);
    }
}
