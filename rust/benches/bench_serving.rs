//! Bench: the unified `ServingEngine` — throughput scaling with core count
//! on the Table VI baseline architecture, with results asserted bit-identical
//! to the sequential cycle-accurate core every round, plus the cost of the
//! live control plane (reconfigure-per-batch vs rebuild-per-config).

use std::collections::BTreeMap;

use quantisenc::config::registers::RegisterFile;
use quantisenc::config::{ModelConfig, Topology};
use quantisenc::coordinator::control::ReconfigProgram;
use quantisenc::coordinator::serving::{ServingEngine, ServingOptions};
use quantisenc::datasets::rng::XorShift64Star;
use quantisenc::datasets::{Dataset, Sample, Split};
use quantisenc::fixed::Q5_3;
use quantisenc::hdl::Core;
use quantisenc::util::bench::quick;
use quantisenc::util::json::Json;

/// Serving throughput over a sparse (Gaussian radius-1) wide layer — the
/// topology-aware store makes the first layer's synaptic work O(3·N)
/// instead of O(N²) per active row, which is what lets a fixed engine
/// serve much wider input layers.
fn bench_sparse_topology() {
    let cfg = ModelConfig::with_topologies(
        &[400, 400, 10],
        &[Topology::Gaussian { radius: 1 }, Topology::AllToAll],
        Q5_3,
    )
    .unwrap();
    let mut rng = XorShift64Star::new(0x5E_22);
    let weights: Vec<Vec<i32>> = cfg
        .layers()
        .iter()
        .map(|l| {
            let mask = l.topology.mask(l.fan_in, l.neurons).unwrap();
            mask.iter()
                .map(|&a| if a == 0 { 0 } else { rng.below(255) as i32 - 127 })
                .collect()
        })
        .collect();
    let regs = RegisterFile::new(Q5_3);
    let samples: Vec<Sample> = (0..16)
        .map(|_| {
            let t_steps = 20;
            let spikes = (0..t_steps * 400).map(|_| (rng.uniform() < 0.3) as u8).collect();
            Sample { spikes, t_steps, inputs: 400, label: 0 }
        })
        .collect();

    // Determinism gate against the sequential core.
    let mut core = Core::new(cfg.clone());
    core.load_weights(&weights).unwrap();
    core.registers = regs.clone();
    let reference: Vec<_> = samples.iter().map(|s| core.run(s)).collect();
    let mut engine =
        ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_cores(2)).unwrap();
    let out = engine.run_batch(&samples).unwrap();
    for (i, (r, want)) in out.iter().zip(&reference).enumerate() {
        assert_eq!(r.counts, want.counts, "gaussian serving sample {i} diverged");
    }
    println!(
        "gaussian_r1 400x400x10 shard stores {} words (dense would be {})",
        engine.synapse_words_per_shard(),
        400 * 400 + 400 * 10
    );
    quick("serving_engine/gaussian_r1_400_16_streams_T20", || {
        std::hint::black_box(engine.run_batch(std::hint::black_box(&samples)).unwrap());
    });
}

/// Lane-batched serving: the same gaussian-r1 N=400 engine at lane widths
/// 1 / 8 / 64. At width L the feeder packs L round-robin-assigned samples
/// per shard into one `SpikeMatrix` per timestep, so each synaptic row
/// fetch and each stage-channel hop is amortized over L streams — this is
/// the PR's acceptance point (≥ 2× samples/s at 64 vs 1). Every width is
/// first proven bit-identical to the sequential core (ragged batch: the
/// stream count is deliberately not a multiple of 64), then timed; the
/// report lands in `BENCH_batched.json` for `repro bench-check`.
fn bench_batched() {
    let cfg = ModelConfig::with_topologies(
        &[400, 400, 10],
        &[Topology::Gaussian { radius: 1 }, Topology::AllToAll],
        Q5_3,
    )
    .unwrap();
    let mut rng = XorShift64Star::new(0x5E_44);
    let weights: Vec<Vec<i32>> = cfg
        .layers()
        .iter()
        .map(|l| {
            let mask = l.topology.mask(l.fan_in, l.neurons).unwrap();
            mask.iter()
                .map(|&a| if a == 0 { 0 } else { rng.below(255) as i32 - 127 })
                .collect()
        })
        .collect();
    let regs = RegisterFile::new(Q5_3);
    // 144 streams on 2 shards = 72 per shard: one full 64-lane group plus
    // a ragged 8-lane tail, with unequal stream lengths.
    let samples: Vec<Sample> = (0..144)
        .map(|i| {
            let t_steps = 16 + (i % 3) * 4;
            let spikes = (0..t_steps * 400).map(|_| (rng.uniform() < 0.3) as u8).collect();
            Sample { spikes, t_steps, inputs: 400, label: 0 }
        })
        .collect();
    let mut core = Core::new(cfg.clone());
    core.load_weights(&weights).unwrap();
    core.registers = regs.clone();
    let reference: Vec<_> = samples.iter().map(|s| core.run(s)).collect();

    let mut throughputs: Vec<(usize, f64)> = Vec::new();
    let mut mat_misses = 0u64;
    let mut plane_misses = 0u64;
    for lane_width in [1usize, 8, 64] {
        let mut engine = ServingEngine::new(
            &cfg,
            &weights,
            &regs,
            ServingOptions::with_lanes(2, lane_width),
        )
        .unwrap();
        // Determinism gate: every lane width must match the sequential
        // core bit-for-bit (counts AND full activity ledger).
        let out = engine.run_batch(&samples).unwrap();
        for (i, (r, want)) in out.iter().zip(&reference).enumerate() {
            assert_eq!(r.counts, want.counts, "lanes={lane_width} sample {i} diverged");
            assert_eq!(r.stats, want.stats, "lanes={lane_width} sample {i} ledger diverged");
        }
        let r = quick(&format!("serving_batched/lane_width_{lane_width}_144_streams"), || {
            std::hint::black_box(engine.run_batch(std::hint::black_box(&samples)).unwrap());
        });
        // Record the measured miss counts; the zero-miss gate fires after
        // the JSON report is written so BENCH_batched.json always carries
        // the real numbers (repro bench-check re-checks them).
        mat_misses += engine.matrix_pool_misses();
        plane_misses += engine.plane_pool_misses();
        throughputs.push((lane_width, r.per_sec() * samples.len() as f64));
    }

    let lane1 = throughputs.iter().find(|&&(l, _)| l == 1).unwrap().1;
    let lane64 = throughputs.iter().find(|&&(l, _)| l == 64).unwrap().1;
    println!("\nlane-batched serving throughput (gaussian-r1 400x400x10, samples/s):");
    for (l, tput) in &throughputs {
        println!("  lane width {l:>2}: {tput:>10.1}");
    }
    println!("lane 64 over lane 1: {:.2}x (gate: >= 2x)", lane64 / lane1);

    if let Ok(path) = std::env::var("BENCH_BATCHED_JSON") {
        let mut root = BTreeMap::new();
        root.insert("bench".to_string(), Json::Str("batched".to_string()));
        root.insert("arch".to_string(), Json::Str("400x400x10".to_string()));
        root.insert("topology".to_string(), Json::Str("gaussian:1".to_string()));
        root.insert("streams".to_string(), Json::Num(samples.len() as f64));
        root.insert("speedup_lane64_over_lane1".to_string(), Json::Num(lane64 / lane1));
        root.insert("matrix_pool_misses".to_string(), Json::Num(mat_misses as f64));
        root.insert(
            "by_lane_width".to_string(),
            Json::Arr(
                throughputs
                    .iter()
                    .map(|&(l, tput)| {
                        let mut o = BTreeMap::new();
                        o.insert("lane_width".to_string(), Json::Num(l as f64));
                        o.insert("samples_per_s".to_string(), Json::Num(tput));
                        Json::Obj(o)
                    })
                    .collect(),
            ),
        );
        let json = Json::Obj(root);
        std::fs::write(&path, format!("{json}\n")).expect("write BENCH_BATCHED_JSON");
        println!("wrote {path}");
    }

    // Zero-alloc gate, after the report exists (so a miss shows up in the
    // archived JSON rather than vanishing with a pre-write panic).
    assert_eq!(mat_misses, 0, "lane streaming allocated matrices (pool underprovisioned)");
    assert_eq!(plane_misses, 0, "streaming allocated planes (pool underprovisioned)");
}

/// Load-imbalance case (the PR-9 robustness satellite): a skewed stream
/// mix — lane groups alternating heavy (T=60) and light (T=4) — that a
/// static round-robin group schedule would pile onto half the shards (the
/// even groups, all heavy, land on shards 0 and 2; shards 1 and 3 idle on
/// light work). The adaptive dispatcher hands every ready group to the
/// shard with the least cumulative dispatched step-cost, so an idle shard
/// steals the next heavy group from the hot one.
///
/// The balance assertion runs on the engine's exact dispatch ledger — the
/// `t_max + 1` per-group cost that `least_loaded` greedily minimizes, with
/// its first-minimum tie-break — replayed here over the same group
/// sequence the feeder forms (consecutive streams, groups of `LANES`).
/// The mix is fixed, so both imbalance ratios are deterministic: 1.85
/// under round-robin, 1.29 under least-loaded. The engine run itself is
/// gated bit-exact against the sequential core like every other case.
fn bench_load_imbalance() {
    const CORES: usize = 4;
    const LANES: usize = 8;
    const GROUPS: usize = 12;
    let cfg = ModelConfig::parse_arch("64x32x10", Q5_3).unwrap();
    let mut rng = XorShift64Star::new(0x5E_55);
    let weights: Vec<Vec<i32>> = cfg
        .layers()
        .iter()
        .map(|l| (0..l.fan_in * l.neurons).map(|_| rng.below(255) as i32 - 127).collect())
        .collect();
    let regs = RegisterFile::new(Q5_3);
    let samples: Vec<Sample> = (0..GROUPS * LANES)
        .map(|i| {
            let t_steps = if (i / LANES) % 2 == 0 { 60 } else { 4 };
            let spikes =
                (0..t_steps * cfg.inputs()).map(|_| (rng.uniform() < 0.3) as u8).collect();
            Sample { spikes, t_steps, inputs: cfg.inputs(), label: 0 }
        })
        .collect();

    // Replay both schedules over the engine's cost model.
    let group_cost: Vec<u64> = samples
        .chunks(LANES)
        .map(|g| g.iter().map(|s| s.t_steps as u64).max().unwrap() + 1)
        .collect();
    let mut round_robin = [0u64; CORES];
    for (g, &c) in group_cost.iter().enumerate() {
        round_robin[g % CORES] += c;
    }
    let mut least_loaded = [0u64; CORES];
    for &c in &group_cost {
        let shard = (0..CORES).min_by_key(|&s| least_loaded[s]).unwrap();
        least_loaded[shard] += c;
    }
    let imbalance = |load: &[u64; CORES]| {
        let max = *load.iter().max().unwrap() as f64;
        max / (load.iter().sum::<u64>() as f64 / CORES as f64)
    };
    let (rr, ll) = (imbalance(&round_robin), imbalance(&least_loaded));
    println!("hot/cold mix, dispatch-ledger imbalance (max shard / mean):");
    println!("  static round-robin: {rr:.2}x   least-loaded: {ll:.2}x");
    assert!(rr > 1.8, "mix must actually be skewed under round-robin (got {rr:.2}x)");
    assert!(ll < 1.3, "least-loaded dispatch must flatten the hot shard (got {ll:.2}x)");
    assert!(
        least_loaded.iter().max() < round_robin.iter().max(),
        "the stealer must shorten the critical shard"
    );

    // Bit-exactness gate, then timing, on the real engine.
    let mut core = Core::new(cfg.clone());
    core.load_weights(&weights).unwrap();
    core.registers = regs.clone();
    let mut engine =
        ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_lanes(CORES, LANES))
            .unwrap();
    let out = engine.run_batch(&samples).unwrap();
    for (i, r) in out.iter().enumerate() {
        let want = core.run(&samples[i]);
        assert_eq!(r.counts, want.counts, "hot/cold sample {i} diverged");
        assert_eq!(r.stats, want.stats, "hot/cold sample {i} ledger diverged");
    }
    quick("serving_imbalance/4_cores_lane8_hot_cold_mix", || {
        std::hint::black_box(engine.run_batch(std::hint::black_box(&samples)).unwrap());
    });
}

/// The Table X sweep pattern: visit several register configs over the same
/// deployed weights. Compares reprogramming one live engine through the
/// control plane against tearing the engine down and rebuilding it per
/// config — the §VI-I "software-defined" dividend on the serving path.
fn bench_live_reconfig() {
    let cfg = ModelConfig::parse_arch("256x128x10", Q5_3).unwrap();
    let mut rng = XorShift64Star::new(0x5E_33);
    let weights: Vec<Vec<i32>> = cfg
        .layers()
        .iter()
        .map(|l| (0..l.fan_in * l.neurons).map(|_| rng.below(255) as i32 - 127).collect())
        .collect();
    let base = RegisterFile::new(Q5_3);
    let samples: Vec<_> = (0..8u64).map(|i| Dataset::Smnist.sample(i, Split::Test, 20)).collect();
    let configs: Vec<RegisterFile> = [0.8, 1.0, 1.2, 1.5]
        .iter()
        .map(|&vth| {
            let mut r = base.clone();
            r.set_vth(vth).unwrap();
            r
        })
        .collect();

    let mut engine =
        ServingEngine::new(&cfg, &weights, &base, ServingOptions::with_cores(2)).unwrap();
    let live = quick("reconfig/control_plane_4_configs_8_streams", || {
        let control = engine.control_plane();
        for regs in &configs {
            control.apply(ReconfigProgram::from_registers(regs)).unwrap();
            std::hint::black_box(engine.run_batch(std::hint::black_box(&samples)).unwrap());
        }
    });
    let rebuild = quick("reconfig/rebuild_engine_4_configs_8_streams", || {
        for regs in &configs {
            let mut fresh =
                ServingEngine::new(&cfg, &weights, regs, ServingOptions::with_cores(2)).unwrap();
            std::hint::black_box(fresh.run_batch(std::hint::black_box(&samples)).unwrap());
        }
    });
    println!(
        "reconfigure-live vs rebuild-per-config: {:.2}x (cfg_in beats so far: {})",
        rebuild.mean.as_secs_f64() / live.mean.as_secs_f64(),
        engine.bus().cfg_writes
    );
}

fn main() {
    println!("== bench_serving (ServingEngine scaling) ==");
    let cfg = ModelConfig::parse_arch("256x128x10", Q5_3).unwrap();
    let mut rng = XorShift64Star::new(0x5E_11);
    let weights: Vec<Vec<i32>> = cfg
        .layers()
        .iter()
        .map(|l| (0..l.fan_in * l.neurons).map(|_| rng.below(255) as i32 - 127).collect())
        .collect();
    let regs = RegisterFile::new(Q5_3);
    let samples: Vec<_> = (0..32u64).map(|i| Dataset::Smnist.sample(i, Split::Test, 40)).collect();

    // Sequential reference (baseline + determinism oracle).
    let mut core = Core::new(cfg.clone());
    core.load_weights(&weights).unwrap();
    core.registers = regs.clone();
    let reference: Vec<_> = samples.iter().map(|s| core.run(s)).collect();
    let seq = quick("sequential_core/32_streams_T40", || {
        for s in &samples {
            std::hint::black_box(core.run(s));
        }
    });

    let mut throughputs = Vec::new();
    for cores in [1usize, 2, 4, 8] {
        let mut engine =
            ServingEngine::new(&cfg, &weights, &regs, ServingOptions::with_cores(cores)).unwrap();
        // Determinism gate: every engine configuration must match the
        // sequential core bit-for-bit before it is allowed on the chart.
        let out = engine.run_batch(&samples).unwrap();
        for (i, (r, want)) in out.iter().zip(&reference).enumerate() {
            assert_eq!(r.counts, want.counts, "cores={cores} sample {i} diverged");
            assert_eq!(r.prediction, want.prediction, "cores={cores} sample {i}");
        }
        let r = quick(&format!("serving_engine/{cores}_cores_32_streams_T40"), || {
            std::hint::black_box(engine.run_batch(std::hint::black_box(&samples)).unwrap());
        });
        throughputs.push((cores, r.per_sec() * samples.len() as f64));
    }

    println!("\nbit-exactness: all core counts identical to the sequential core");
    println!("throughput scaling (streams/sec, batch of {}):", samples.len());
    println!("  sequential: {:>10.1}", seq.per_sec() * samples.len() as f64);
    for (cores, tput) in &throughputs {
        println!("  {cores} cores:    {tput:>10.1}");
    }

    println!("\n== bench_serving (sparse topology) ==");
    bench_sparse_topology();

    println!("\n== bench_serving (lane-batched datapath) ==");
    bench_batched();

    println!("\n== bench_serving (load imbalance) ==");
    bench_load_imbalance();

    println!("\n== bench_serving (live control plane) ==");
    bench_live_reconfig();

    // Merge engine throughput into the hot-path perf report written by
    // bench_layer (the BENCH_hotpath.json the Makefile's bench-smoke
    // validates and CI archives): end-to-end samples/s for every core
    // count on the zero-alloc packed streaming path, next to the
    // sequential-core baseline.
    if let Ok(path) = std::env::var("BENCH_HOTPATH_JSON") {
        // The layer section must already exist (bench_layer writes it, and
        // the Makefile runs it first). Failing loudly here beats writing an
        // engine-only report that `repro bench-check` would reject with a
        // confusing missing-key error.
        let text = std::fs::read_to_string(&path).unwrap_or_else(|e| {
            panic!("{path}: no hot-path report to merge into ({e}); run bench_layer first")
        });
        let mut root = match Json::parse(&text) {
            Ok(Json::Obj(o)) => o,
            other => panic!("{path}: not a JSON object ({other:?}); rerun bench_layer"),
        };
        let mut engine = BTreeMap::new();
        engine.insert("streams".to_string(), Json::Num(samples.len() as f64));
        engine.insert("t_steps".to_string(), Json::Num(40.0));
        engine.insert(
            "sequential_samples_per_s".to_string(),
            Json::Num(seq.per_sec() * samples.len() as f64),
        );
        engine.insert(
            "by_cores".to_string(),
            Json::Arr(
                throughputs
                    .iter()
                    .map(|&(cores, tput)| {
                        let mut o = BTreeMap::new();
                        o.insert("cores".to_string(), Json::Num(cores as f64));
                        o.insert("samples_per_s".to_string(), Json::Num(tput));
                        Json::Obj(o)
                    })
                    .collect(),
            ),
        );
        root.insert("engine".to_string(), Json::Obj(engine));
        let json = Json::Obj(root);
        std::fs::write(&path, format!("{json}\n")).expect("write BENCH_HOTPATH_JSON");
        println!("merged engine throughput into {path}");
    }
}
