//! Bench: end-to-end per-dataset inference (Table XI workloads) on both
//! backends — cycle-accurate hdl core and PJRT executable — plus the
//! experiment generators themselves (tables are cheap; this guards against
//! regressions making `repro all` slow).

use quantisenc::datasets::{Dataset, Split};
use quantisenc::experiments;
use quantisenc::runtime::{artifacts::Manifest, Runtime};
use quantisenc::util::bench::quick;

fn main() {
    println!("== bench_e2e (Table XI workloads) ==");
    let manifest = match Manifest::load(&quantisenc::artifacts_dir()) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping (run `make artifacts` first): {e:#}");
            return;
        }
    };
    let rt = Runtime::cpu().expect("PJRT CPU client");

    for ds in Dataset::all() {
        let art = match manifest.model(ds.label(), "Q5.3") {
            Ok(a) => a,
            Err(_) => continue,
        };
        let sample = ds.sample(0, Split::Test, art.t_steps);

        let (_, mut core) = experiments::core_from_artifact(&art).unwrap();
        quick(&format!("hdl/{}_{}_T{}", ds.label(), art.qname, art.t_steps), || {
            std::hint::black_box(core.run(std::hint::black_box(&sample)));
        });

        let exe = rt.load_model(&art).unwrap();
        quick(&format!("pjrt/{}_{}_T{}", ds.label(), art.qname, art.t_steps), || {
            std::hint::black_box(exe.run(std::hint::black_box(&sample.spikes)).unwrap());
        });

        // Dataset generation itself (the encoder feeding the pipeline).
        quick(&format!("datagen/{}_T{}", ds.label(), art.t_steps), || {
            std::hint::black_box(ds.sample(7, Split::Test, art.t_steps));
        });
    }

    // Experiment generators (figure/table regeneration latency).
    quick("experiments/fig3+fig4", || {
        std::hint::black_box(experiments::dynamics::fig3());
        std::hint::black_box(experiments::dynamics::fig4());
    });
    quick("experiments/table4+5+12+9", || {
        std::hint::black_box(experiments::resources_exp::table4());
        std::hint::black_box(experiments::resources_exp::table5());
        std::hint::black_box(experiments::resources_exp::table12());
        std::hint::black_box(experiments::dse_exp::table9());
    });
}
