//! Bench: single-LIF-neuron step throughput per quantization — the
//! workload behind paper Table IV (plus the Fig. 3/4 dynamics probes).

use quantisenc::config::registers::RegisterFile;
use quantisenc::fixed::{Q17_15, Q2_2, Q5_3, Q9_7};
use quantisenc::hdl::neuron::{DynamicsProbe, LifNeuron};
use quantisenc::util::bench::quick;

fn main() {
    println!("== bench_neuron (Table IV workload) ==");
    for qs in [Q2_2, Q5_3, Q9_7, Q17_15] {
        let regs = RegisterFile::new(qs);
        let drive = qs.from_float(1.5);
        let mut n = LifNeuron::new();
        quick(&format!("neuron_step/{qs} x10k"), || {
            for _ in 0..10_000 {
                std::hint::black_box(n.step(std::hint::black_box(drive), &regs, qs));
            }
        });
    }
    // The Fig. 3/4 probe (40-step trace, Q9.7).
    let mut regs = RegisterFile::new(Q9_7);
    regs.set_vth(10.0).unwrap();
    let probe = DynamicsProbe::new(Q9_7, regs);
    quick("dynamics_probe/fig3_trace_40steps", || {
        std::hint::black_box(probe.step_input(20.0, 40));
    });
}
