//! Bench: full-core sample inference per architecture — the workload
//! behind paper Table VI (and the activity source for its power column).

use quantisenc::config::ModelConfig;
use quantisenc::datasets::rng::XorShift64Star;
use quantisenc::datasets::{Dataset, Sample, Split};
use quantisenc::fixed::{Q5_3, Q9_7};
use quantisenc::hdl::Core;
use quantisenc::util::bench::quick;

fn random_core(arch: &str, qs: quantisenc::fixed::QSpec) -> Core {
    let cfg = ModelConfig::parse_arch(arch, qs).unwrap();
    let mut core = Core::new(cfg.clone());
    let mut rng = XorShift64Star::new(0xC0DE);
    let weights: Vec<Vec<i32>> = cfg
        .layers()
        .iter()
        .map(|l| {
            (0..l.fan_in * l.neurons)
                .map(|_| {
                    let lim = qs.max_raw().min(127) as u64;
                    (rng.below(2 * lim + 1) as i32) - lim as i32
                })
                .collect()
        })
        .collect();
    core.load_weights(&weights).unwrap();
    core
}

fn main() {
    println!("== bench_core (Table VI workload) ==");
    let sample = Dataset::Smnist.sample(0, Split::Test, 40);
    for (arch, qs) in [
        ("256x128x10", Q5_3),
        ("256x128x10", Q9_7),
        ("256x256x10", Q5_3),
        ("256x256x256x10", Q5_3),
    ] {
        let mut core = random_core(arch, qs);
        quick(&format!("core_run/{arch}_{qs}_T40"), || {
            std::hint::black_box(core.run(std::hint::black_box(&sample)));
        });
    }
    // Wide Table IX shape.
    let mut wide = random_core("256x1470x10", Q5_3);
    let s2 = Sample { spikes: sample.spikes.clone(), t_steps: 40, inputs: 256, label: 0 };
    quick("core_run/256x1470x10_Q5.3_T40 (Table IX wide)", || {
        std::hint::black_box(wide.run(std::hint::black_box(&s2)));
    });
}
