//! Bench: one-layer timestep per connection modality — the workload behind
//! paper Table V (one-to-one, conv 3x3/5x5, FC-128/256/512), now measuring
//! the topology-aware sparse stores: each case reports its physical storage
//! words, the synaptic accumulates actually performed per step, and the
//! step latency, so the O(nnz) win of banded/diagonal storage over the
//! dense walk is visible in the output.
//!
//! Set `BENCH_TOPOLOGY_JSON=<path>` to additionally emit the results as a
//! JSON report (the Makefile `bench-smoke` target writes
//! `BENCH_topology.json`).

use std::collections::BTreeMap;

use quantisenc::config::{LayerConfig, MemKind, Topology};
use quantisenc::datasets::rng::XorShift64Star;
use quantisenc::fixed::Q5_3;
use quantisenc::hdl::Layer;
use quantisenc::util::bench::quick;
use quantisenc::util::json::Json;

struct CaseResult {
    name: String,
    topology: String,
    m: usize,
    n: usize,
    /// Physical storage words (α=1 synapses) vs the dense M×N footprint.
    words: usize,
    dense_words: usize,
    /// Synaptic accumulates in one timestep of the benchmarked spike vector.
    synaptic_ops: u64,
    gated_ops: u64,
    mean_us: f64,
    steps_per_sec: f64,
}

fn bench_topology(name: &str, m: usize, n: usize, topo: Topology, density: f64) -> CaseResult {
    let cfg = LayerConfig { fan_in: m, neurons: n, topology: topo };
    let mut layer = Layer::new(&cfg, Q5_3, MemKind::Bram);
    let mut rng = XorShift64Star::new(0xB0B);
    // Program all alpha=1 weights.
    let mask = topo.mask(m, n).unwrap();
    for pre in 0..m {
        for post in 0..n {
            if mask[pre * n + post] == 1 {
                layer
                    .memory_mut()
                    .write(pre, post, rng.below(255) as i32 - 127)
                    .unwrap();
            }
        }
    }
    // Spike stream from a dedicated, shape-seeded generator so every
    // topology of the same (m, density) sees the identical input — the
    // synaptic-op comparison across topologies is then apples-to-apples.
    let mut srng = XorShift64Star::new(0x5EED ^ ((m as u64) << 20) ^ (density * 1e3) as u64);
    let spikes: Vec<u8> = (0..m).map(|_| (srng.uniform() < density) as u8).collect();
    let mut out = Vec::new();
    let stats = layer.step(&spikes, &mut out);
    let r = quick(&format!("layer_step/{name}"), || {
        std::hint::black_box(layer.step(std::hint::black_box(&spikes), &mut out));
    });
    CaseResult {
        name: name.to_string(),
        topology: topo.label(),
        m,
        n,
        words: layer.memory().synapses(),
        dense_words: m * n,
        synaptic_ops: stats.synaptic_ops,
        gated_ops: stats.gated_ops,
        mean_us: r.mean.as_secs_f64() * 1e6,
        steps_per_sec: r.per_sec(),
    }
}

fn case_json(c: &CaseResult) -> Json {
    let mut o = BTreeMap::new();
    o.insert("name".to_string(), Json::Str(c.name.clone()));
    o.insert("topology".to_string(), Json::Str(c.topology.clone()));
    o.insert("m".to_string(), Json::Num(c.m as f64));
    o.insert("n".to_string(), Json::Num(c.n as f64));
    o.insert("storage_words".to_string(), Json::Num(c.words as f64));
    o.insert("dense_words".to_string(), Json::Num(c.dense_words as f64));
    o.insert("synaptic_ops_per_step".to_string(), Json::Num(c.synaptic_ops as f64));
    o.insert("gated_ops_per_step".to_string(), Json::Num(c.gated_ops as f64));
    o.insert("mean_us".to_string(), Json::Num(c.mean_us));
    o.insert("steps_per_sec".to_string(), Json::Num(c.steps_per_sec));
    Json::Obj(o)
}

fn main() {
    println!("== bench_layer (Table V workload, topology-aware stores) ==");
    let mut cases = Vec::new();
    cases.push(bench_topology("one_to_one_128", 128, 128, Topology::OneToOne, 0.3));
    cases.push(bench_topology("conv3x3_256", 256, 256, Topology::Gaussian { radius: 1 }, 0.3));
    cases.push(bench_topology("conv5x5_256", 256, 256, Topology::Gaussian { radius: 2 }, 0.3));
    cases.push(bench_topology("fc_128", 128, 128, Topology::AllToAll, 0.3));
    cases.push(bench_topology("fc_256", 256, 256, Topology::AllToAll, 0.3));
    cases.push(bench_topology("fc_512", 512, 512, Topology::AllToAll, 0.3));
    // The acceptance-point comparison: N=400 at matched spike streams.
    cases.push(bench_topology("one_to_one_400", 400, 400, Topology::OneToOne, 0.3));
    cases.push(bench_topology("gaussian_r1_400", 400, 400, Topology::Gaussian { radius: 1 }, 0.3));
    cases.push(bench_topology("gaussian_r2_400", 400, 400, Topology::Gaussian { radius: 2 }, 0.3));
    cases.push(bench_topology("fc_400", 400, 400, Topology::AllToAll, 0.3));
    // Gating sensitivity: the same FC layer at different input densities.
    for density in [0.05, 0.3, 0.9] {
        cases.push(bench_topology(
            &format!("fc_256_density_{density}"),
            256,
            256,
            Topology::AllToAll,
            density,
        ));
    }

    println!("\nstorage + per-step synaptic work (one timestep, density 0.3 unless noted):");
    for c in &cases {
        println!(
            "  {:24} {:>9} words (dense {:>9})  {:>8} synaptic ops/step",
            c.name, c.words, c.dense_words, c.synaptic_ops
        );
    }
    let find = |name: &str| cases.iter().find(|c| c.name == name).unwrap();
    let (gauss, full) = (find("gaussian_r1_400"), find("fc_400"));
    println!(
        "\ngaussian_r1_400 vs fc_400: {:.1}x fewer synaptic ops, {:.1}x fewer storage words",
        full.synaptic_ops as f64 / gauss.synaptic_ops as f64,
        full.words as f64 / gauss.words as f64
    );

    if let Ok(path) = std::env::var("BENCH_TOPOLOGY_JSON") {
        let mut root = BTreeMap::new();
        root.insert("bench".to_string(), Json::Str("bench_layer/topology".to_string()));
        root.insert(
            "ops_ratio_fc400_over_gaussian_r1_400".to_string(),
            Json::Num(full.synaptic_ops as f64 / gauss.synaptic_ops as f64),
        );
        root.insert("cases".to_string(), Json::Arr(cases.iter().map(case_json).collect()));
        let json = Json::Obj(root);
        std::fs::write(&path, format!("{json}\n")).expect("write BENCH_TOPOLOGY_JSON");
        println!("wrote {path}");
    }
}
