//! Bench: one-layer timestep per connection modality — the workload behind
//! paper Table V (one-to-one, conv 3x3/5x5, FC-128/256/512), now measuring
//! the topology-aware sparse stores: each case reports its physical storage
//! words, the synaptic accumulates actually performed per step, and the
//! step latency, so the O(nnz) win of banded/diagonal storage over the
//! dense walk is visible in the output.
//!
//! Set `BENCH_TOPOLOGY_JSON=<path>` to additionally emit the results as a
//! JSON report (the Makefile `bench-smoke` target writes
//! `BENCH_topology.json`).
//!
//! A second section benchmarks the **event-driven hot path**: the packed
//! [`SpikePlane`] datapath (`Layer::step_plane` — trailing_zeros row
//! iteration, bulk gating charge, SoA quiescence skip) against the retained
//! dense scalar reference (`Layer::step_scalar`) on the same layer, same
//! weights, same spike stream — after a 200-step bit-exactness pre-gate.
//! Set `BENCH_HOTPATH_JSON=<path>` to emit `BENCH_hotpath.json` (per-case
//! scalar/packed ns-per-step and the N=400 @ 2%-firing speedup the
//! acceptance gate checks; `bench_serving` merges its engine throughput
//! into the same file and `repro bench-check` validates it).

use std::collections::BTreeMap;

use quantisenc::config::registers::RegisterFile;
use quantisenc::config::{LayerConfig, MemKind, Topology};
use quantisenc::datasets::rng::XorShift64Star;
use quantisenc::fixed::Q5_3;
use quantisenc::hdl::neuron::LaneKernel;
use quantisenc::hdl::{ActivityStats, Layer, SpikeMatrix, SpikePlane};
use quantisenc::util::bench::quick;
use quantisenc::util::json::Json;

struct CaseResult {
    name: String,
    topology: String,
    m: usize,
    n: usize,
    /// Physical storage words (α=1 synapses) vs the dense M×N footprint.
    words: usize,
    dense_words: usize,
    /// Synaptic accumulates in one timestep of the benchmarked spike vector.
    synaptic_ops: u64,
    gated_ops: u64,
    mean_us: f64,
    steps_per_sec: f64,
}

fn bench_topology(name: &str, m: usize, n: usize, topo: Topology, density: f64) -> CaseResult {
    let cfg = LayerConfig { fan_in: m, neurons: n, topology: topo };
    let mut layer = Layer::new(&cfg, Q5_3, MemKind::Bram);
    let mut rng = XorShift64Star::new(0xB0B);
    // Program all alpha=1 weights.
    let mask = topo.mask(m, n).unwrap();
    for pre in 0..m {
        for post in 0..n {
            if mask[pre * n + post] == 1 {
                layer
                    .memory_mut()
                    .write(pre, post, rng.below(255) as i32 - 127)
                    .unwrap();
            }
        }
    }
    // Spike stream from a dedicated, shape-seeded generator so every
    // topology of the same (m, density) sees the identical input — the
    // synaptic-op comparison across topologies is then apples-to-apples.
    let mut srng = XorShift64Star::new(0x5EED ^ ((m as u64) << 20) ^ (density * 1e3) as u64);
    let spikes: Vec<u8> = (0..m).map(|_| (srng.uniform() < density) as u8).collect();
    let mut out = Vec::new();
    let stats = layer.step(&spikes, &mut out);
    let r = quick(&format!("layer_step/{name}"), || {
        std::hint::black_box(layer.step(std::hint::black_box(&spikes), &mut out));
    });
    CaseResult {
        name: name.to_string(),
        topology: topo.label(),
        m,
        n,
        words: layer.memory().synapses(),
        dense_words: m * n,
        synaptic_ops: stats.synaptic_ops,
        gated_ops: stats.gated_ops,
        mean_us: r.mean.as_secs_f64() * 1e6,
        steps_per_sec: r.per_sec(),
    }
}

fn case_json(c: &CaseResult) -> Json {
    let mut o = BTreeMap::new();
    o.insert("name".to_string(), Json::Str(c.name.clone()));
    o.insert("topology".to_string(), Json::Str(c.topology.clone()));
    o.insert("m".to_string(), Json::Num(c.m as f64));
    o.insert("n".to_string(), Json::Num(c.n as f64));
    o.insert("storage_words".to_string(), Json::Num(c.words as f64));
    o.insert("dense_words".to_string(), Json::Num(c.dense_words as f64));
    o.insert("synaptic_ops_per_step".to_string(), Json::Num(c.synaptic_ops as f64));
    o.insert("gated_ops_per_step".to_string(), Json::Num(c.gated_ops as f64));
    o.insert("mean_us".to_string(), Json::Num(c.mean_us));
    o.insert("steps_per_sec".to_string(), Json::Num(c.steps_per_sec));
    Json::Obj(o)
}

struct HotpathResult {
    name: String,
    topology: String,
    n: usize,
    firing_rate: f64,
    firing_rows: usize,
    scalar_ns: f64,
    packed_ns: f64,
    speedup: f64,
}

/// Scalar-reference vs packed-plane step latency on an N×N layer of the
/// given topology at the given input firing rate. Both paths are first
/// proven bit-identical over 200 steps of the benchmarked stream (vmem,
/// spikes, full ledger), then timed on twin layers with the same weights.
///
/// The acceptance case is Gaussian radius-1 at N = 400 / 2% firing — the
/// paper's conv3x3-analog connectivity, where event-driven execution pays
/// off fully: ~8 firing rows touch ≤ 24 of 400 neurons, so the packed
/// path retires ~24 synaptic accumulates, ~24 full LIF updates, and ~376
/// three-compare quiescence skips, while the scalar reference still scans
/// all 400 rows and runs all 400 LIF updates. The all-to-all cases are
/// reported alongside (there every firing row touches all N activation
/// registers, so only the row scan is saved and the win is modest).
fn bench_hotpath_case(name: &str, n: usize, topo: Topology, firing: f64) -> HotpathResult {
    let cfg = LayerConfig { fan_in: n, neurons: n, topology: topo };
    let mut rng = XorShift64Star::new(0x407_407);
    let mask = topo.mask(n, n).unwrap();
    let weights: Vec<i32> = mask
        .iter()
        .map(|&a| if a == 0 { 0 } else { rng.below(255) as i32 - 127 })
        .collect();
    let regs = RegisterFile::new(Q5_3);
    let mut srng = XorShift64Star::new(0xF1_7E ^ ((n as u64) << 16) ^ (firing * 1e4) as u64);
    let mut spikes: Vec<u8> = (0..n).map(|_| (srng.uniform() < firing) as u8).collect();
    if spikes.iter().all(|&s| s == 0) {
        spikes[0] = 1; // keep the nominal rate non-degenerate
    }
    let firing_rows = spikes.iter().filter(|&&s| s != 0).count();
    let plane = SpikePlane::from_bytes(&spikes);

    let mut scalar = Layer::new(&cfg, Q5_3, MemKind::Bram);
    scalar.memory_mut().load_dense(&weights).unwrap();
    let mut packed = scalar.clone();

    // Bit-exactness pre-gate: the twins must stay identical while the
    // membrane state evolves under the benchmarked stream.
    let mut out_b = Vec::new();
    let mut out_p = SpikePlane::default();
    for t in 0..200 {
        let s = scalar.step_scalar(&spikes, &mut out_b, &regs);
        let p = packed.step_plane(&plane, &mut out_p, &regs);
        assert_eq!(out_p.to_bytes(), out_b, "{name} t={t} spikes diverged");
        assert_eq!(packed.vmem_slice(), scalar.vmem_slice(), "{name} t={t} vmem diverged");
        assert_eq!(p, s, "{name} t={t} ledger diverged");
    }

    let rs = quick(&format!("hotpath/{name}/scalar"), || {
        std::hint::black_box(scalar.step_scalar(std::hint::black_box(&spikes), &mut out_b, &regs));
    });
    let rp = quick(&format!("hotpath/{name}/packed"), || {
        std::hint::black_box(packed.step_plane(std::hint::black_box(&plane), &mut out_p, &regs));
    });
    let scalar_ns = rs.median.as_secs_f64() * 1e9;
    let packed_ns = rp.median.as_secs_f64() * 1e9;
    HotpathResult {
        name: name.to_string(),
        topology: topo.label(),
        n,
        firing_rate: firing,
        firing_rows,
        scalar_ns,
        packed_ns,
        speedup: scalar_ns / packed_ns,
    }
}

/// Lane-batched layer stepping: one `Layer::step_lanes` call carrying 64
/// independent spike streams vs 64 single-sample `step_plane` calls on a
/// twin — same weights, same streams, proven bit-identical (per-lane vmem,
/// spikes, ledger) over a pre-gate before timing. The reported speedup is
/// per *sample-step*: the lane path fetches each firing line's synaptic
/// row once for all 64 lanes instead of once per lane.
fn bench_lane_case(name: &str, n: usize, topo: Topology, firing: f64) -> (String, f64) {
    const LANES: usize = 64;
    let cfg = LayerConfig { fan_in: n, neurons: n, topology: topo };
    let mut rng = XorShift64Star::new(0x1A4E ^ (n as u64) << 8);
    let mask = topo.mask(n, n).unwrap();
    let weights: Vec<i32> = mask
        .iter()
        .map(|&a| if a == 0 { 0 } else { rng.below(255) as i32 - 127 })
        .collect();
    let regs = RegisterFile::new(Q5_3);
    let streams: Vec<Vec<u8>> =
        (0..LANES).map(|_| (0..n).map(|_| (rng.uniform() < firing) as u8).collect()).collect();
    let mut matrix = SpikeMatrix::new(n, LANES);
    for (l, s) in streams.iter().enumerate() {
        matrix.load_lane_bytes(l, s);
    }
    let planes: Vec<SpikePlane> = streams.iter().map(|s| SpikePlane::from_bytes(s)).collect();

    let mut batched = Layer::new(&cfg, Q5_3, MemKind::Bram);
    batched.memory_mut().load_dense(&weights).unwrap();
    let mut twins: Vec<Layer> = (0..LANES).map(|_| batched.clone()).collect();

    // Bit-exactness pre-gate over 50 steps of evolving membrane state.
    let mut mat_out = SpikeMatrix::default();
    let mut stats = vec![ActivityStats::default(); LANES];
    let mut plane_out = SpikePlane::default();
    let mut gather = SpikePlane::default();
    for t in 0..50 {
        batched.step_lanes(&matrix, &mut mat_out, &regs, u64::MAX, &mut stats);
        for (l, twin) in twins.iter_mut().enumerate() {
            let want = twin.step_plane(&planes[l], &mut plane_out, &regs);
            mat_out.lane_plane_into(l, &mut gather);
            assert_eq!(gather, plane_out, "{name} t={t} lane {l} spikes diverged");
            assert_eq!(batched.lane_vmem(l), twin.vmem_slice(), "{name} t={t} lane {l} vmem");
            assert_eq!(stats[l], want, "{name} t={t} lane {l} ledger");
        }
    }

    let rb = quick(&format!("lanes/{name}/batched_x64"), || {
        std::hint::black_box(batched.step_lanes(
            std::hint::black_box(&matrix),
            &mut mat_out,
            &regs,
            u64::MAX,
            &mut stats,
        ));
    });
    let twin = &mut twins[0];
    let rs = quick(&format!("lanes/{name}/single_x1"), || {
        for p in &planes {
            std::hint::black_box(twin.step_plane(std::hint::black_box(p), &mut plane_out, &regs));
        }
    });
    // Per-sample-step cost: batched does 64 sample-steps per call, the
    // single-sample loop runs the same 64 streams through one layer.
    let speedup = rs.median.as_secs_f64() / rb.median.as_secs_f64();
    (name.to_string(), speedup)
}

struct SimdResult {
    name: String,
    kernel: &'static str,
    scalar_ns: f64,
    simd_ns: f64,
    speedup: f64,
}

/// Pinned-kernel lane-step twins: the same 64-lane bank stepped with
/// `LaneKernel::Scalar` vs the widest vector tier `LaneKernel::auto`
/// resolves on this host (AVX2 → SSE2 → scalar). Both twins are first
/// proven bit-identical over 120 steps of evolving membrane state (spike
/// matrices, per-lane vmem, ledgers), then timed on `step_lanes` alone.
///
/// The acceptance case is one-to-one at 35% firing: ActGen retires ~one
/// accumulate per firing (line, lane) pair, so the per-call cost is
/// dominated by the N×64 neuron sweep the vector tiers batch 4–8 lanes
/// per instruction. The all-to-all case is reported alongside — there the
/// shared ActGen scatter dominates the call and dilutes the sweep win. On
/// hosts where `auto` falls back to scalar the twins are the same kernel
/// and the reported speedup is ~1.0x; `bench-check` reads the `kernel`
/// field and skips the SIMD gate in that case.
fn bench_simd_case(name: &str, n: usize, topo: Topology, firing: f64) -> SimdResult {
    const LANES: usize = 64;
    let cfg = LayerConfig { fan_in: n, neurons: n, topology: topo };
    let mut rng = XorShift64Star::new(0x51D_u64 ^ (n as u64) << 9);
    let mask = topo.mask(n, n).unwrap();
    let weights: Vec<i32> = mask
        .iter()
        .map(|&a| if a == 0 { 0 } else { rng.below(255) as i32 - 127 })
        .collect();
    let regs = RegisterFile::new(Q5_3);
    let mut matrix = SpikeMatrix::new(n, LANES);
    for l in 0..LANES {
        let stream: Vec<u8> = (0..n).map(|_| (rng.uniform() < firing) as u8).collect();
        matrix.load_lane_bytes(l, &stream);
    }

    let mut scalar = Layer::new(&cfg, Q5_3, MemKind::Bram);
    scalar.memory_mut().load_dense(&weights).unwrap();
    let mut vector = scalar.clone();
    scalar.set_lane_kernel(Some(LaneKernel::Scalar));
    let kernel = LaneKernel::auto(Q5_3);
    vector.set_lane_kernel(Some(kernel));

    // Bit-exactness pre-gate: pinned twins must stay identical while the
    // lane banks evolve under the benchmarked stream.
    let mut out_s = SpikeMatrix::default();
    let mut out_v = SpikeMatrix::default();
    let mut stats_s = vec![ActivityStats::default(); LANES];
    let mut stats_v = vec![ActivityStats::default(); LANES];
    for t in 0..120 {
        scalar.step_lanes(&matrix, &mut out_s, &regs, u64::MAX, &mut stats_s);
        vector.step_lanes(&matrix, &mut out_v, &regs, u64::MAX, &mut stats_v);
        assert_eq!(out_v, out_s, "{name} t={t} spikes diverged across kernels");
        assert_eq!(stats_v, stats_s, "{name} t={t} ledger diverged across kernels");
        for l in 0..LANES {
            assert_eq!(vector.lane_vmem(l), scalar.lane_vmem(l), "{name} t={t} lane {l} vmem");
        }
    }

    let rs = quick(&format!("simd/{name}/scalar"), || {
        std::hint::black_box(scalar.step_lanes(
            std::hint::black_box(&matrix),
            &mut out_s,
            &regs,
            u64::MAX,
            &mut stats_s,
        ));
    });
    let rv = quick(&format!("simd/{name}/{}", kernel.name()), || {
        std::hint::black_box(vector.step_lanes(
            std::hint::black_box(&matrix),
            &mut out_v,
            &regs,
            u64::MAX,
            &mut stats_v,
        ));
    });
    let scalar_ns = rs.median.as_secs_f64() * 1e9;
    let simd_ns = rv.median.as_secs_f64() * 1e9;
    SimdResult {
        name: name.to_string(),
        kernel: kernel.name(),
        scalar_ns,
        simd_ns,
        speedup: scalar_ns / simd_ns,
    }
}

fn simd_json(c: &SimdResult) -> Json {
    let mut o = BTreeMap::new();
    o.insert("name".to_string(), Json::Str(c.name.clone()));
    o.insert("kernel".to_string(), Json::Str(c.kernel.to_string()));
    o.insert("scalar_ns_per_step".to_string(), Json::Num(c.scalar_ns));
    o.insert("simd_ns_per_step".to_string(), Json::Num(c.simd_ns));
    o.insert("speedup".to_string(), Json::Num(c.speedup));
    Json::Obj(o)
}

fn hotpath_json(c: &HotpathResult) -> Json {
    let mut o = BTreeMap::new();
    o.insert("name".to_string(), Json::Str(c.name.clone()));
    o.insert("topology".to_string(), Json::Str(c.topology.clone()));
    o.insert("n".to_string(), Json::Num(c.n as f64));
    o.insert("firing_rate".to_string(), Json::Num(c.firing_rate));
    o.insert("firing_rows".to_string(), Json::Num(c.firing_rows as f64));
    o.insert("scalar_ns_per_step".to_string(), Json::Num(c.scalar_ns));
    o.insert("packed_ns_per_step".to_string(), Json::Num(c.packed_ns));
    o.insert("speedup".to_string(), Json::Num(c.speedup));
    Json::Obj(o)
}

fn main() {
    println!("== bench_layer (Table V workload, topology-aware stores) ==");
    let mut cases = Vec::new();
    cases.push(bench_topology("one_to_one_128", 128, 128, Topology::OneToOne, 0.3));
    cases.push(bench_topology("conv3x3_256", 256, 256, Topology::Gaussian { radius: 1 }, 0.3));
    cases.push(bench_topology("conv5x5_256", 256, 256, Topology::Gaussian { radius: 2 }, 0.3));
    cases.push(bench_topology("fc_128", 128, 128, Topology::AllToAll, 0.3));
    cases.push(bench_topology("fc_256", 256, 256, Topology::AllToAll, 0.3));
    cases.push(bench_topology("fc_512", 512, 512, Topology::AllToAll, 0.3));
    // The acceptance-point comparison: N=400 at matched spike streams.
    cases.push(bench_topology("one_to_one_400", 400, 400, Topology::OneToOne, 0.3));
    cases.push(bench_topology("gaussian_r1_400", 400, 400, Topology::Gaussian { radius: 1 }, 0.3));
    cases.push(bench_topology("gaussian_r2_400", 400, 400, Topology::Gaussian { radius: 2 }, 0.3));
    cases.push(bench_topology("fc_400", 400, 400, Topology::AllToAll, 0.3));
    // Gating sensitivity: the same FC layer at different input densities.
    for density in [0.05, 0.3, 0.9] {
        cases.push(bench_topology(
            &format!("fc_256_density_{density}"),
            256,
            256,
            Topology::AllToAll,
            density,
        ));
    }

    println!("\nstorage + per-step synaptic work (one timestep, density 0.3 unless noted):");
    for c in &cases {
        println!(
            "  {:24} {:>9} words (dense {:>9})  {:>8} synaptic ops/step",
            c.name, c.words, c.dense_words, c.synaptic_ops
        );
    }
    let find = |name: &str| cases.iter().find(|c| c.name == name).unwrap();
    let (gauss, full) = (find("gaussian_r1_400"), find("fc_400"));
    println!(
        "\ngaussian_r1_400 vs fc_400: {:.1}x fewer synaptic ops, {:.1}x fewer storage words",
        full.synaptic_ops as f64 / gauss.synaptic_ops as f64,
        full.words as f64 / gauss.words as f64
    );

    if let Ok(path) = std::env::var("BENCH_TOPOLOGY_JSON") {
        let mut root = BTreeMap::new();
        root.insert("bench".to_string(), Json::Str("bench_layer/topology".to_string()));
        root.insert(
            "ops_ratio_fc400_over_gaussian_r1_400".to_string(),
            Json::Num(full.synaptic_ops as f64 / gauss.synaptic_ops as f64),
        );
        root.insert("cases".to_string(), Json::Arr(cases.iter().map(case_json).collect()));
        let json = Json::Obj(root);
        std::fs::write(&path, format!("{json}\n")).expect("write BENCH_TOPOLOGY_JSON");
        println!("wrote {path}");
    }

    println!("\n== bench_layer (event-driven hot path: scalar reference vs packed planes) ==");
    let g1 = Topology::Gaussian { radius: 1 };
    let hp_cases = vec![
        bench_hotpath_case("gaussian_r1_400_firing_2pct", 400, g1, 0.02),
        bench_hotpath_case("gaussian_r1_400_firing_5pct", 400, g1, 0.05),
        bench_hotpath_case("one_to_one_400_firing_2pct", 400, Topology::OneToOne, 0.02),
        bench_hotpath_case("fc_400_firing_2pct", 400, Topology::AllToAll, 0.02),
        bench_hotpath_case("fc_400_firing_30pct", 400, Topology::AllToAll, 0.30),
        bench_hotpath_case("fc_256_firing_2pct", 256, Topology::AllToAll, 0.02),
    ];
    println!("\nlayer step latency, scalar reference vs packed event-driven path:");
    for c in &hp_cases {
        println!(
            "  {:28} ({:>3} firing rows)  scalar {:>9.0} ns  packed {:>9.0} ns  {:>5.1}x",
            c.name, c.firing_rows, c.scalar_ns, c.packed_ns, c.speedup
        );
    }
    let accept = hp_cases.iter().find(|c| c.name == "gaussian_r1_400_firing_2pct").unwrap();
    println!(
        "\nacceptance point N=400 @ 2% firing (gaussian r1): {:.1}x (gate: >= 3x)",
        accept.speedup
    );

    println!("\n== bench_layer (lane-batched stepping: 64 lanes per call vs 64 single steps) ==");
    let lane_cases = vec![
        bench_lane_case("gaussian_r1_400_firing_30pct", 400, g1, 0.30),
        bench_lane_case("gaussian_r1_400_firing_2pct", 400, g1, 0.02),
        bench_lane_case("fc_256_firing_2pct", 256, Topology::AllToAll, 0.02),
    ];
    println!("\nper-sample-step speedup of the 64-lane batched path:");
    for (name, speedup) in &lane_cases {
        println!("  {name:28} {speedup:>5.1}x");
    }

    println!("\n== bench_layer (SIMD lane kernels: pinned scalar vs widest vector tier) ==");
    let simd_cases = vec![
        bench_simd_case("one_to_one_400_firing_35pct", 400, Topology::OneToOne, 0.35),
        bench_simd_case("one_to_one_400_firing_90pct", 400, Topology::OneToOne, 0.90),
        bench_simd_case("gaussian_r1_400_firing_35pct", 400, g1, 0.35),
        bench_simd_case("fc_256_firing_35pct", 256, Topology::AllToAll, 0.35),
    ];
    println!("\nlane-step latency, pinned scalar kernel vs `LaneKernel::auto`:");
    for c in &simd_cases {
        println!(
            "  {:28} [{:6}] scalar {:>9.0} ns  simd {:>9.0} ns  {:>5.1}x",
            c.name, c.kernel, c.scalar_ns, c.simd_ns, c.speedup
        );
    }
    let simd_accept = simd_cases.iter().find(|c| c.name == "one_to_one_400_firing_35pct").unwrap();
    println!(
        "\nSIMD acceptance point one-to-one N=400 @ 35% firing: {:.1}x on `{}` (gate: >= 1.5x \
         unless the auto kernel is the scalar fallback)",
        simd_accept.speedup, simd_accept.kernel
    );

    if let Ok(path) = std::env::var("BENCH_HOTPATH_JSON") {
        let mut root = BTreeMap::new();
        root.insert("bench".to_string(), Json::Str("hotpath".to_string()));
        root.insert(
            "layer_speedup_n400_2pct".to_string(),
            Json::Num(accept.speedup),
        );
        root.insert(
            "layer_cases".to_string(),
            Json::Arr(hp_cases.iter().map(hotpath_json).collect()),
        );
        root.insert(
            "lane_cases".to_string(),
            Json::Arr(
                lane_cases
                    .iter()
                    .map(|(name, speedup)| {
                        let mut o = BTreeMap::new();
                        o.insert("name".to_string(), Json::Str(name.clone()));
                        o.insert("lane64_speedup_per_sample_step".to_string(), Json::Num(*speedup));
                        Json::Obj(o)
                    })
                    .collect(),
            ),
        );
        root.insert("simd_kernel".to_string(), Json::Str(simd_accept.kernel.to_string()));
        root.insert("simd_speedup_lane_step".to_string(), Json::Num(simd_accept.speedup));
        root.insert(
            "simd_cases".to_string(),
            Json::Arr(simd_cases.iter().map(simd_json).collect()),
        );
        let json = Json::Obj(root);
        std::fs::write(&path, format!("{json}\n")).expect("write BENCH_HOTPATH_JSON");
        println!("wrote {path}");
    }
}
