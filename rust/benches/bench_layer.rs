//! Bench: one-layer timestep per connection modality — the workload behind
//! paper Table V (one-to-one, conv 3x3/5x5, FC-128/256/512).

use quantisenc::config::{LayerConfig, MemKind, Topology};
use quantisenc::datasets::rng::XorShift64Star;
use quantisenc::fixed::Q5_3;
use quantisenc::hdl::Layer;
use quantisenc::util::bench::quick;

fn bench_topology(name: &str, m: usize, n: usize, topo: Topology, density: f64) {
    let cfg = LayerConfig { fan_in: m, neurons: n, topology: topo };
    let mut layer = Layer::new(&cfg, Q5_3, MemKind::Bram);
    let mut rng = XorShift64Star::new(0xB0B);
    // Program all alpha=1 weights.
    let mask = topo.mask(m, n).unwrap();
    for pre in 0..m {
        for post in 0..n {
            if mask[pre * n + post] == 1 {
                layer
                    .memory_mut()
                    .write(pre, post, rng.below(255) as i32 - 127)
                    .unwrap();
            }
        }
    }
    let spikes: Vec<u8> = (0..m).map(|_| (rng.uniform() < density) as u8).collect();
    let mut out = Vec::new();
    quick(&format!("layer_step/{name}"), || {
        std::hint::black_box(layer.step(std::hint::black_box(&spikes), &mut out));
    });
}

fn main() {
    println!("== bench_layer (Table V workload) ==");
    bench_topology("one_to_one_128", 128, 128, Topology::OneToOne, 0.3);
    bench_topology("conv3x3_256", 256, 256, Topology::Gaussian { radius: 1 }, 0.3);
    bench_topology("conv5x5_256", 256, 256, Topology::Gaussian { radius: 2 }, 0.3);
    bench_topology("fc_128", 128, 128, Topology::AllToAll, 0.3);
    bench_topology("fc_256", 256, 256, Topology::AllToAll, 0.3);
    bench_topology("fc_512", 512, 512, Topology::AllToAll, 0.3);
    // Gating sensitivity: the same FC layer at different input densities.
    for density in [0.05, 0.3, 0.9] {
        bench_topology(&format!("fc_256_density_{density}"), 256, 256, Topology::AllToAll, density);
    }
}
