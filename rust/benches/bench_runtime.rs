//! Bench: the PJRT request path — single LIF-step kernel artifact and the
//! full T-step dataset forwards (the latency/throughput columns behind the
//! Table XI serving story). Requires `make artifacts`.

use quantisenc::datasets::{Dataset, Split};
use quantisenc::runtime::{artifacts::Manifest, Runtime};
use quantisenc::util::bench::quick;

fn main() {
    println!("== bench_runtime (PJRT hot path) ==");
    let manifest = match Manifest::load(&quantisenc::artifacts_dir()) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping (run `make artifacts` first): {e:#}");
            return;
        }
    };
    let rt = Runtime::cpu().expect("PJRT CPU client");

    // Single-layer single-step kernel.
    if let Ok(path) = manifest.kernel_hlo_path("lif_step_Q53") {
        let exe = rt.compile_hlo_file(&path).expect("compile lif_step");
        let spikes = vec![1i32; 256];
        let weights = vec![3i32; 256 * 128];
        let state = vec![0i32; 128];
        let regs = vec![2i32, 8, 8, 0, 2, 0];
        let args = [
            xla::Literal::vec1(&spikes),
            xla::Literal::vec1(&weights).reshape(&[256, 128]).unwrap(),
            xla::Literal::vec1(&state),
            xla::Literal::vec1(&state),
            xla::Literal::vec1(&regs),
        ];
        quick("pjrt/lif_step_Q53 (256->128)", || {
            let arg_refs: Vec<&xla::Literal> = args.iter().collect();
            let out = exe.execute::<&xla::Literal>(&arg_refs).unwrap()[0][0]
                .to_literal_sync()
                .unwrap();
            std::hint::black_box(out);
        });
    }

    // Full dataset forwards.
    for (ds, q) in [(Dataset::Smnist, "Q5.3"), (Dataset::Smnist, "Q9.7"), (Dataset::Dvs, "Q5.3"), (Dataset::Shd, "Q5.3")] {
        let art = match manifest.model(ds.label(), q) {
            Ok(a) => a,
            Err(_) => continue,
        };
        let exe = rt.load_model(&art).expect("load model");
        let sample = ds.sample(0, Split::Test, art.t_steps);
        quick(&format!("pjrt/forward_{}_{q}_T{}", ds.label(), art.t_steps), || {
            std::hint::black_box(exe.run(std::hint::black_box(&sample.spikes)).unwrap());
        });
    }
}
