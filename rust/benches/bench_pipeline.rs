//! Bench: §VI-G / Fig. 8 — pipelined streaming vs sequential dataflow, and
//! batch multicore scaling. Wall-clock numbers complement the analytic
//! cycle model printed at the end.

use quantisenc::config::registers::RegisterFile;
use quantisenc::config::ModelConfig;
use quantisenc::coordinator::multicore::MultiCore;
use quantisenc::coordinator::pipeline::{run_pipelined, ScheduleModel};
use quantisenc::datasets::rng::XorShift64Star;
use quantisenc::datasets::{Dataset, Split};
use quantisenc::fixed::Q5_3;
use quantisenc::hdl::Core;
use quantisenc::util::bench::quick;

fn main() {
    println!("== bench_pipeline (§VI-G / Fig. 8 workload) ==");
    let cfg = ModelConfig::parse_arch("256x128x10", Q5_3).unwrap();
    let mut rng = XorShift64Star::new(0xF10);
    let weights: Vec<Vec<i32>> = cfg
        .layers()
        .iter()
        .map(|l| (0..l.fan_in * l.neurons).map(|_| rng.below(255) as i32 - 127).collect())
        .collect();
    let regs = RegisterFile::new(Q5_3);
    let samples: Vec<_> = (0..16u64).map(|i| Dataset::Smnist.sample(i, Split::Test, 40)).collect();

    let mut core = Core::new(cfg.clone());
    core.load_weights(&weights).unwrap();
    quick("sequential/16_streams_T40", || {
        for s in &samples {
            std::hint::black_box(core.run(s));
        }
    });

    quick("pipelined/16_streams_T40 (thread per layer)", || {
        std::hint::black_box(run_pipelined(&cfg, &weights, &regs, &samples).unwrap());
    });

    for cores in [1usize, 2, 4] {
        let mut mc = MultiCore::new(&cfg, &weights, &regs, cores).unwrap();
        quick(&format!("multicore/{cores}_cores_16_streams"), || {
            std::hint::black_box(mc.run_batch(&samples));
        });
    }

    let m = ScheduleModel::paper_baseline();
    println!(
        "\nanalytic Fig. 8 schedule: pipelined {:.2} fps vs dataflow {:.2} fps (+{:.1}%)",
        m.pipelined_fps(),
        m.dataflow_fps(),
        100.0 * (m.speedup() - 1.0)
    );
}
