"""Pallas kernel vs pure-jnp oracle — THE core L1 correctness signal.

hypothesis sweeps shapes, Qn.q settings, register values (all four reset
modes, refractory periods), tile widths, and adversarial weight/vmem values;
the kernel must match the reference bit for bit, every output, every lane.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import fixedpoint as fp
from compile.kernels import lif, ref

QSPECS = [fp.Q2_2, fp.Q3_1, fp.Q5_3, fp.Q9_7]


def run_both(spikes, w, vmem, refc, regs, qs, block_n):
    k = lif.lif_layer_step(jnp.asarray(spikes), jnp.asarray(w), jnp.asarray(vmem),
                           jnp.asarray(refc), jnp.asarray(regs), qspec=qs, block_n=block_n)
    r = ref.lif_layer_step_ref(spikes, w, vmem, refc, regs, qs)
    return [np.asarray(x) for x in k], [np.asarray(x) for x in r]


@st.composite
def lif_case(draw):
    qs = draw(st.sampled_from(QSPECS))
    m = draw(st.integers(1, 96))
    n = draw(st.integers(1, 160))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    w = rng.integers(qs.min_raw, qs.max_raw + 1, (m, n)).astype(np.int32)
    spikes = (rng.random(m) < draw(st.floats(0.0, 1.0))).astype(np.int32)
    vmem = rng.integers(qs.min_raw, qs.max_raw + 1, n).astype(np.int32)
    refc = rng.integers(0, 4, n).astype(np.int32)
    regs = np.array([
        rng.integers(qs.min_raw, qs.max_raw + 1),
        rng.integers(qs.min_raw, qs.max_raw + 1),
        rng.integers(qs.min_raw, qs.max_raw + 1),
        rng.integers(qs.min_raw, qs.max_raw + 1),
        draw(st.sampled_from([ref.RESET_DEFAULT, ref.RESET_TO_ZERO,
                              ref.RESET_BY_SUBTRACTION, ref.RESET_TO_CONSTANT])),
        draw(st.integers(0, 5)),
    ], np.int32)
    block_n = draw(st.sampled_from([8, 32, 128, 256]))
    return spikes, w, vmem, refc, regs, qs, block_n


@given(lif_case())
@settings(max_examples=60, deadline=None)
def test_kernel_matches_ref_bitexact(case):
    spikes, w, vmem, refc, regs, qs, block_n = case
    kout, rout = run_both(spikes, w, vmem, refc, regs, qs, block_n)
    for a, b, name in zip(kout, rout, ("spikes", "vmem", "refcnt")):
        assert np.array_equal(a, b), f"{name} mismatch ({qs.name}, block={block_n})"


def test_padding_lanes_do_not_leak():
    """N not a multiple of block_n: padded lanes must not alter real lanes."""
    qs = fp.Q5_3
    rng = np.random.default_rng(3)
    for n in (1, 7, 127, 129, 130):
        m = 16
        w = rng.integers(qs.min_raw, qs.max_raw + 1, (m, n)).astype(np.int32)
        spikes = (rng.random(m) < 0.5).astype(np.int32)
        vmem = rng.integers(qs.min_raw, qs.max_raw + 1, n).astype(np.int32)
        refc = np.zeros(n, np.int32)
        regs = np.array([2, 8, 8, 0, ref.RESET_BY_SUBTRACTION, 0], np.int32)
        kout, rout = run_both(spikes, w, vmem, refc, regs, qs, 128)
        for a, b in zip(kout, rout):
            assert a.shape == (n,)
            assert np.array_equal(a, b)


def test_block_width_invariance():
    """Result must be identical for any tile width (tiling is pure schedule)."""
    qs = fp.Q9_7
    rng = np.random.default_rng(5)
    m, n = 64, 96
    w = rng.integers(qs.min_raw, qs.max_raw + 1, (m, n)).astype(np.int32)
    spikes = (rng.random(m) < 0.4).astype(np.int32)
    vmem = rng.integers(qs.min_raw, qs.max_raw + 1, n).astype(np.int32)
    refc = rng.integers(0, 3, n).astype(np.int32)
    regs = np.array([26, 128, 128, 0, ref.RESET_DEFAULT, 1], np.int32)
    outs = []
    for bn in (8, 16, 96, 128, 512):
        k, _ = run_both(spikes, w, vmem, refc, regs, qs, bn)
        outs.append(k)
    for o in outs[1:]:
        for a, b in zip(outs[0], o):
            assert np.array_equal(a, b)


def test_extreme_values_wrap_identically():
    """All-min / all-max weights and vmem: wrapping paths agree."""
    qs = fp.Q5_3
    m, n = 32, 16
    for fill_w, fill_v in ((qs.min_raw, qs.min_raw), (qs.max_raw, qs.max_raw),
                           (qs.min_raw, qs.max_raw)):
        w = np.full((m, n), fill_w, np.int32)
        spikes = np.ones(m, np.int32)
        vmem = np.full(n, fill_v, np.int32)
        refc = np.zeros(n, np.int32)
        regs = np.array([qs.max_raw, qs.max_raw, 1, 0, ref.RESET_BY_SUBTRACTION, 0], np.int32)
        kout, rout = run_both(spikes, w, vmem, refc, regs, qs, 8)
        for a, b in zip(kout, rout):
            assert np.array_equal(a, b)


def test_multi_step_trace_agreement():
    """State threading over 50 steps: kernel trace == ref trace exactly."""
    qs = fp.Q5_3
    rng = np.random.default_rng(11)
    m, n = 24, 40
    w = rng.integers(qs.min_raw, qs.max_raw + 1, (m, n)).astype(np.int32)
    regs = np.array([2, 8, 16, 0, ref.RESET_TO_ZERO, 2], np.int32)
    vk = vr = np.zeros(n, np.int32)
    rk = rr = np.zeros(n, np.int32)
    for t in range(50):
        spikes = (rng.random(m) < 0.3).astype(np.int32)
        sk, vk, rk = (np.asarray(x) for x in lif.lif_layer_step(
            jnp.asarray(spikes), jnp.asarray(w), jnp.asarray(vk), jnp.asarray(rk),
            jnp.asarray(regs), qspec=qs, block_n=16))
        sr, vr, rr = (np.asarray(x) for x in ref.lif_layer_step_ref(spikes, w, vr, rr, regs, qs))
        assert np.array_equal(sk, sr) and np.array_equal(vk, vr) and np.array_equal(rk, rr), t


def test_vmem_bytes_model():
    qs = fp.Q5_3
    b = lif.vmem_bytes(256, 128, qs)
    assert b == 256 * 128 * 1 + 3 * 128 * 4 + 256 * 4 + ref.NUM_REGS * 4
    assert lif.vmem_bytes(700, 256, fp.Q9_7) < 16 * 2**20  # fits VMEM
