"""L2 model tests: spec validation, quantized forward, float forward, traces."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.fixedpoint import Q5_3, Q9_7
from compile.kernels import ref
from compile.kernels import synapse as syn


@pytest.fixture(scope="module")
def small():
    spec = model.ModelSpec((16, 8, 4), Q5_3)
    params = model.init_params(spec, jax.random.PRNGKey(0))
    qw = [jnp.asarray(w) for w in model.quantize_params(params, spec)]
    regs = jnp.asarray(model.default_regs(spec))
    rng = np.random.default_rng(1)
    spikes = jnp.asarray((rng.random((12, 16)) < 0.3).astype(np.int32))
    return spec, params, qw, regs, spikes


class TestModelSpec:
    def test_counts_match_paper_baseline(self):
        spec = model.ModelSpec((256, 128, 10), Q5_3)
        assert spec.total_neurons == 394          # paper §VI-D
        assert spec.total_synapses == 34048       # paper Table VI row 1
        assert spec.name == "256x128x10"

    def test_table6_row4_counts(self):
        spec = model.ModelSpec((256, 256, 256, 10), Q5_3)
        assert spec.total_neurons == 778
        assert spec.total_synapses == 133632

    def test_rejects_single_size(self):
        with pytest.raises(ValueError):
            model.ModelSpec((10,), Q5_3)

    def test_topology_arity_checked(self):
        with pytest.raises(ValueError):
            model.ModelSpec((4, 4), Q5_3, topologies=("all_to_all", "one_to_one"))

    def test_mixed_topologies(self):
        spec = model.ModelSpec((8, 8, 4), Q5_3, topologies=(syn.ONE_TO_ONE, syn.ALL_TO_ALL))
        assert spec.layers[0].synapses == 8
        assert spec.layers[1].synapses == 32


class TestQuantizedForward:
    def test_kernel_equals_ref_path(self, small):
        spec, _, qw, regs, spikes = small
        a = model.quantized_forward(spikes, qw, regs, spec, use_kernel=True)
        b = model.quantized_forward(spikes, qw, regs, spec, use_kernel=False)
        assert np.array_equal(np.asarray(a["out_spikes"]), np.asarray(b["out_spikes"]))
        assert np.array_equal(np.asarray(a["layer_spike_totals"]),
                              np.asarray(b["layer_spike_totals"]))

    def test_output_shapes(self, small):
        spec, _, qw, regs, spikes = small
        out = model.quantized_forward(spikes, qw, regs, spec)
        assert out["out_spikes"].shape == (12, 4)
        assert out["counts"].shape == (4,)
        assert out["layer_spike_totals"].shape == (2,)

    def test_counts_are_column_sums(self, small):
        spec, _, qw, regs, spikes = small
        out = model.quantized_forward(spikes, qw, regs, spec)
        assert np.array_equal(np.asarray(out["counts"]),
                              np.asarray(out["out_spikes"]).sum(axis=0))

    def test_spike_totals_monotone_in_input(self, small):
        """More input spikes (with positive drive) can't reduce totals to > input case... we
        assert the weaker structural invariant: zero input -> zero spikes."""
        spec, _, qw, regs, _ = small
        silent = jnp.zeros((12, 16), jnp.int32)
        out = model.quantized_forward(silent, qw, regs, spec)
        assert int(np.asarray(out["layer_spike_totals"]).sum()) == 0

    def test_outputs_binary(self, small):
        spec, _, qw, regs, spikes = small
        out = np.asarray(model.quantized_forward(spikes, qw, regs, spec)["out_spikes"])
        assert set(np.unique(out)).issubset({0, 1})


class TestFloatForward:
    def test_batched_and_single_agree(self, small):
        spec, params, _, _, spikes = small
        fs = jnp.asarray(np.asarray(spikes), jnp.float32)
        single = model.float_forward(fs, params, spec)
        batched = model.float_forward(fs[None], params, spec)
        assert np.allclose(np.asarray(single), np.asarray(batched[0]))

    def test_gradient_flows(self, small):
        spec, params, _, _, spikes = small
        fs = jnp.asarray(np.asarray(spikes), jnp.float32)

        def loss(ps):
            return jnp.sum(model.float_forward(fs, ps, spec))

        grads = jax.grad(loss)(params)
        total = sum(float(jnp.abs(g).sum()) for g in grads)
        assert total > 0.0, "surrogate gradient must be nonzero"

    def test_surrogate_forward_is_heaviside(self):
        x = jnp.array([-1.0, -1e-6, 0.0, 1e-6, 1.0])
        out = np.asarray(model.spike_surrogate(x))
        assert np.array_equal(out, [0, 0, 1, 1, 1])


class TestTraces:
    def test_quantized_trace_matches_forward_state(self, small):
        spec, _, qw, regs, spikes = small
        trace = model.quantized_membrane_trace(spikes, qw, regs, spec, layer=1)
        assert trace.shape == (12, 4)
        out = model.quantized_forward(spikes, qw, regs, spec)
        assert np.array_equal(np.asarray(trace[-1]), np.asarray(out["final_vmem"][1]))

    def test_float_trace_shape(self, small):
        spec, params, _, _, spikes = small
        fs = jnp.asarray(np.asarray(spikes), jnp.float32)
        trace = model.float_membrane_trace(fs, params, spec, layer=0)
        assert trace.shape == (12, 8)

    def test_quantization_rmse_ordering(self):
        """Fig. 12: RMSE(Q9.7) < RMSE(Q5.3) vs the float software trace."""
        spec97 = model.ModelSpec((16, 8, 4), Q9_7)
        spec53 = model.ModelSpec((16, 8, 4), Q5_3)
        params = model.init_params(spec97, jax.random.PRNGKey(42))
        rng = np.random.default_rng(7)
        spikes = (rng.random((30, 16)) < 0.35).astype(np.int32)
        fs = jnp.asarray(spikes, jnp.float32)
        soft = np.asarray(model.float_membrane_trace(fs, params, spec97, layer=0))
        rmses = {}
        for spec in (spec97, spec53):
            qw = [jnp.asarray(w) for w in model.quantize_params(params, spec)]
            regs = jnp.asarray(model.default_regs(spec))
            hard = np.asarray(model.quantized_membrane_trace(
                jnp.asarray(spikes), qw, regs, spec, layer=0))
            rmses[spec.qspec.name] = float(np.sqrt(np.mean(
                (spec.qspec.to_float(hard) - soft) ** 2)))
        assert rmses["Q9.7"] < rmses["Q5.3"]


class TestRegisters:
    def test_default_regs_values(self):
        spec = model.ModelSpec((4, 2), Q5_3)
        regs = model.default_regs(spec)
        assert regs.tolist() == [
            Q5_3.from_float(0.2), Q5_3.from_float(1.0), Q5_3.from_float(1.0),
            0, ref.RESET_BY_SUBTRACTION, 0]

    def test_reg_vector_layout_is_stable(self):
        """The Rust register file depends on this exact layout."""
        assert (ref.REG_DECAY, ref.REG_GROWTH, ref.REG_VTH, ref.REG_VRESET,
                ref.REG_RESET_MODE, ref.REG_REFRACTORY) == (0, 1, 2, 3, 4, 5)
        assert ref.NUM_REGS == 6
