"""Semantic unit tests for the quantized LIF reference (paper §III-A)."""

import numpy as np
import pytest

from compile.fixedpoint import Q5_3, Q9_7
from compile.kernels import ref


def mk_regs(qs, decay=0.2, growth=1.0, vth=1.0, vreset=0.0, mode=ref.RESET_BY_SUBTRACTION,
            refractory=0):
    return np.array([qs.from_float(decay), qs.from_float(growth), qs.from_float(vth),
                     qs.from_float(vreset), mode, refractory], np.int32)


def step(spikes, w, vmem, refc, regs, qs=Q5_3):
    s, v, r = ref.lif_layer_step_ref(spikes, w, vmem, refc, regs, qs)
    return np.asarray(s), np.asarray(v), np.asarray(r)


ONE = Q5_3.from_float(1.0)  # raw 8


class TestActGen:
    def test_no_spikes_no_activation(self):
        w = np.full((4, 2), 10, np.int32)
        s, v, _ = step(np.zeros(4, np.int32), w, np.zeros(2, np.int32),
                       np.zeros(2, np.int32), mk_regs(Q5_3))
        assert (v == 0).all() and (s == 0).all()

    def test_weighted_sum(self):
        # growth=1.0: v' = act exactly (decay of v=0 is 0).
        w = np.array([[3], [5], [7]], np.int32)
        spikes = np.array([1, 0, 1], np.int32)
        _, v, _ = step(spikes, w, np.zeros(1, np.int32), np.zeros(1, np.int32),
                       mk_regs(Q5_3, vth=10.0))
        assert v[0] == 10  # 3 + 7

    def test_inhibitory_weights_subtract(self):
        w = np.array([[8], [-4]], np.int32)
        spikes = np.array([1, 1], np.int32)
        _, v, _ = step(spikes, w, np.zeros(1, np.int32), np.zeros(1, np.int32),
                       mk_regs(Q5_3, vth=10.0))
        assert v[0] == 4

    def test_activation_wraps(self):
        """ActGen register wraps like the W-bit hardware accumulator."""
        w = np.full((4, 1), 100, np.int32)  # 400 wraps in 8 bits
        spikes = np.ones(4, np.int32)
        _, v, _ = step(spikes, w, np.zeros(1, np.int32), np.zeros(1, np.int32),
                       mk_regs(Q5_3, vth=15.0))
        assert v[0] == Q5_3.wrap(400)


class TestVmemDyn:
    def test_decay_only(self):
        # v=80 (10.0), decay=0.25 -> v' = 80 - 20 = 60
        regs = mk_regs(Q5_3, decay=0.25, vth=15.0)
        _, v, _ = step(np.zeros(1, np.int32), np.zeros((1, 1), np.int32),
                       np.array([80], np.int32), np.zeros(1, np.int32), regs)
        assert v[0] == 60

    def test_growth_scales_activation(self):
        regs = mk_regs(Q5_3, growth=0.5, vth=15.0)
        w = np.array([[16]], np.int32)  # 2.0
        _, v, _ = step(np.ones(1, np.int32), w, np.zeros(1, np.int32),
                       np.zeros(1, np.int32), regs)
        assert v[0] == 8  # 0.5 * 2.0 = 1.0


class TestSpkGen:
    def test_spike_at_threshold(self):
        regs = mk_regs(Q5_3, vth=1.0, mode=ref.RESET_TO_ZERO)
        w = np.array([[ONE]], np.int32)
        s, v, _ = step(np.ones(1, np.int32), w, np.zeros(1, np.int32),
                       np.zeros(1, np.int32), regs)
        assert s[0] == 1 and v[0] == 0  # >= is inclusive

    def test_no_spike_below_threshold(self):
        regs = mk_regs(Q5_3, vth=1.0)
        w = np.array([[ONE - 1]], np.int32)
        s, _, _ = step(np.ones(1, np.int32), w, np.zeros(1, np.int32),
                       np.zeros(1, np.int32), regs)
        assert s[0] == 0


class TestVmemSel:
    @pytest.fixture
    def over_threshold(self):
        # act = 2.0 with vth = 1.0 -> fires; v_new = 16 raw.
        return np.array([[Q5_3.from_float(2.0)]], np.int32)

    def test_reset_to_zero(self, over_threshold):
        regs = mk_regs(Q5_3, mode=ref.RESET_TO_ZERO)
        _, v, _ = step(np.ones(1, np.int32), over_threshold, np.zeros(1, np.int32),
                       np.zeros(1, np.int32), regs)
        assert v[0] == 0

    def test_reset_by_subtraction(self, over_threshold):
        regs = mk_regs(Q5_3, mode=ref.RESET_BY_SUBTRACTION)
        _, v, _ = step(np.ones(1, np.int32), over_threshold, np.zeros(1, np.int32),
                       np.zeros(1, np.int32), regs)
        assert v[0] == 16 - 8  # v_new - vth

    def test_reset_to_constant(self, over_threshold):
        regs = mk_regs(Q5_3, mode=ref.RESET_TO_CONSTANT, vreset=0.5)
        _, v, _ = step(np.ones(1, np.int32), over_threshold, np.zeros(1, np.int32),
                       np.zeros(1, np.int32), regs)
        assert v[0] == Q5_3.from_float(0.5)

    def test_reset_default_decays(self, over_threshold):
        regs = mk_regs(Q5_3, mode=ref.RESET_DEFAULT, decay=0.25)
        _, v, _ = step(np.ones(1, np.int32), over_threshold, np.zeros(1, np.int32),
                       np.zeros(1, np.int32), regs)
        assert v[0] == 16 - 4  # v_new - decay*v_new

    def test_reset_ordering_matches_paper_fig4(self):
        """Over a step drive: default >= subtract >= zero spike counts (Fig. 4)."""
        counts = {}
        w = np.array([[Q5_3.from_float(3.0)]], np.int32)
        for mode in (ref.RESET_DEFAULT, ref.RESET_BY_SUBTRACTION, ref.RESET_TO_ZERO):
            regs = mk_regs(Q5_3, decay=0.2, vth=2.0, mode=mode)
            vmem = np.zeros(1, np.int32)
            refc = np.zeros(1, np.int32)
            total = 0
            for _ in range(40):
                s, vmem, refc = step(np.ones(1, np.int32), w, vmem, refc, regs)
                total += int(s[0])
            counts[mode] = total
        assert counts[ref.RESET_DEFAULT] >= counts[ref.RESET_BY_SUBTRACTION]
        assert counts[ref.RESET_BY_SUBTRACTION] >= counts[ref.RESET_TO_ZERO]
        assert counts[ref.RESET_TO_ZERO] > 0


class TestRefractory:
    def test_holds_vmem_and_blocks_spikes(self):
        regs = mk_regs(Q5_3, vth=1.0, mode=ref.RESET_TO_ZERO, refractory=3)
        w = np.array([[Q5_3.from_float(2.0)]], np.int32)
        vmem = np.zeros(1, np.int32)
        refc = np.zeros(1, np.int32)
        spikes = []
        for _ in range(8):
            s, vmem, refc = step(np.ones(1, np.int32), w, vmem, refc, regs)
            spikes.append(int(s[0]))
        # Fires, then silent for exactly `refractory` steps, then fires again.
        assert spikes == [1, 0, 0, 0, 1, 0, 0, 0]

    def test_fmax_bound(self):
        """Eq. 8: firing frequency <= 1 / refractory_period."""
        for period in (1, 2, 5):
            regs = mk_regs(Q5_3, vth=0.25, mode=ref.RESET_TO_ZERO, refractory=period)
            w = np.array([[Q5_3.from_float(4.0)]], np.int32)
            vmem = np.zeros(1, np.int32)
            refc = np.zeros(1, np.int32)
            total, steps_n = 0, 60
            for _ in range(steps_n):
                s, vmem, refc = step(np.ones(1, np.int32), w, vmem, refc, regs)
                total += int(s[0])
            assert total <= steps_n / period + 1

    def test_counter_decrements_without_spike(self):
        regs = mk_regs(Q5_3, vth=15.0)
        _, _, r = step(np.zeros(1, np.int32), np.zeros((1, 1), np.int32),
                       np.zeros(1, np.int32), np.array([2], np.int32), regs)
        assert r[0] == 1

    def test_counter_floors_at_zero(self):
        regs = mk_regs(Q5_3, vth=15.0)
        _, _, r = step(np.zeros(1, np.int32), np.zeros((1, 1), np.int32),
                       np.zeros(1, np.int32), np.zeros(1, np.int32), regs)
        assert r[0] == 0


class TestRCSettings:
    def test_fig3_spike_ordering(self):
        """Fig. 3: growth (R large, C small) drives spiking; tiny growth = none."""
        qs = Q9_7
        totals = []
        for growth in (1.0, 0.2, 0.1, 0.02):  # R=500M..10M at fixed tau
            regs = np.array([qs.from_float(0.2), qs.from_float(growth),
                             qs.from_float(10.0), 0, ref.RESET_BY_SUBTRACTION, 0], np.int32)
            w = np.array([[qs.from_float(10.5)]], np.int32)  # step drive
            vmem = np.zeros(1, np.int32)
            refc = np.zeros(1, np.int32)
            total = 0
            for _ in range(40):
                s, vmem, refc = (np.asarray(x) for x in
                                 ref.lif_layer_step_ref(np.ones(1, np.int32), w, vmem, refc, regs, qs))
                total += int(s[0])
            totals.append(total)
        assert totals[0] > totals[1] > totals[2] >= totals[3]
        assert totals[3] == 0  # R=10M: never crosses threshold
