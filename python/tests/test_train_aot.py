"""Trainer + AOT pipeline tests (loss decreases; HLO text well-formed)."""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot, model, train
from compile.fixedpoint import Q5_3
from compile.kernels import ref


class TestAdam:
    def test_minimises_quadratic(self):
        import jax.numpy as jnp
        params = [jnp.array([5.0, -3.0])]
        state = train.adam_init(params)
        for _ in range(400):
            grads = [2 * params[0]]
            params, state = train.adam_update(params, grads, state, lr=5e-2)
        assert float(jnp.abs(params[0]).max()) < 1e-2

    def test_state_shapes(self):
        import jax.numpy as jnp
        params = [jnp.zeros((3, 4)), jnp.zeros((4,))]
        st = train.adam_init(params)
        assert st["m"][0].shape == (3, 4) and st["v"][1].shape == (4,)


class TestTraining:
    @pytest.fixture(scope="class")
    def tiny_run(self, tmp_path_factory):
        log = tmp_path_factory.mktemp("t") / "log.json"
        spec = model.ModelSpec((256, 32, 10), Q5_3)
        params, hist = train.train("smnist", spec, steps=120, batch_size=32,
                                   n_train=256, n_test=48, t_steps=15,
                                   log_path=str(log), verbose=False)
        return spec, params, hist, log

    def test_loss_decreases(self, tiny_run):
        _, _, hist, _ = tiny_run
        first = np.mean(hist["loss"][:5])
        last = np.mean(hist["loss"][-5:])
        assert last < first

    def test_better_than_chance(self, tiny_run):
        _, _, hist, _ = tiny_run
        assert hist["final_acc"] > 0.15  # 10 classes -> chance is 0.1

    def test_log_written(self, tiny_run):
        *_, log = tiny_run
        data = json.loads(log.read_text())
        assert data["dataset"] == "smnist" and len(data["loss"]) == 120

    def test_masks_keep_pruned_synapses_zero(self):
        from compile.kernels import synapse as syn
        spec = model.ModelSpec((32, 32, 10), Q5_3, topologies=(syn.ONE_TO_ONE, syn.ALL_TO_ALL))
        params, _ = train.train("smnist_fake", spec, steps=0, n_train=1, n_test=1) \
            if False else (model.init_params(spec, jax.random.PRNGKey(0)), None)
        mask = spec.layers[0].mask()
        assert (np.asarray(params[0])[mask == 0] == 0).all()

    def test_quantized_accuracy_runs(self, tiny_run):
        spec, params, hist, _ = tiny_run
        acc = train.quantized_accuracy(params, spec, "smnist", n_test=24, t_steps=15)
        assert 0.0 <= acc <= 1.0

    def test_spec_dataset_mismatch_rejected(self):
        spec = model.ModelSpec((16, 10), Q5_3)
        with pytest.raises(AssertionError):
            train.train("smnist", spec, steps=1, n_train=4, n_test=4, verbose=False)


class TestAOT:
    def test_lif_step_hlo_text(self):
        text = aot.lower_lif_step(Q5_3, m=32, n=16)
        assert text.startswith("HloModule")
        assert "s32[32,16]" in text  # weight parameter shape present

    def test_forward_hlo_text_parameters(self):
        spec = model.ModelSpec((16, 8, 4), Q5_3)
        text = aot.lower_forward(spec, t_steps=5)
        assert text.startswith("HloModule")
        # spikes, both weight matrices, regs all appear as parameters
        assert "s32[5,16]" in text
        assert "s32[16,8]" in text
        assert "s32[8,4]" in text
        assert f"s32[{ref.NUM_REGS}]" in text

    def test_golden_fixedpoint_selfcheck(self):
        from compile import fixedpoint as fp
        g = aot.golden_fixedpoint()
        assert len(g["cases"]) == 256
        for c in g["cases"][:20]:
            qs = fp.parse(c["q"])
            assert qs.add(c["a"], c["b"]) == c["add"]
            assert qs.mul(c["a"], c["b"]) == c["mul"]

    def test_golden_lif_trace_consistent(self):
        g = aot.golden_lif_trace(Q5_3, t_steps=8)
        assert set(g["traces"]) == {"0", "1", "2", "3"}
        for tr in g["traces"].values():
            assert len(tr["spikes_out"]) == 8
            assert len(tr["vmem"][0]) == g["n"]

    def test_golden_datasets_fields(self):
        g = aot.golden_datasets()
        for name in ("smnist", "dvs", "shd"):
            assert g[name]["nnz"] == sum(g[name]["spike_rows"])
