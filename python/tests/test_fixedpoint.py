"""Unit + property tests for the Qn.q fixed-point substrate (paper §III-C)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import fixedpoint as fp

QSPECS = [fp.Q2_2, fp.Q3_1, fp.Q5_3, fp.Q9_7]


def raw_strategy(qs):
    return st.integers(min_value=qs.min_raw, max_value=qs.max_raw)


class TestQSpec:
    def test_widths(self):
        assert fp.Q5_3.width == 8
        assert fp.Q9_7.width == 16
        assert fp.Q2_2.width == 4

    def test_ranges(self):
        assert fp.Q5_3.max_raw == 127
        assert fp.Q5_3.min_raw == -128
        assert fp.Q9_7.max_raw == 32767

    def test_name_roundtrip(self):
        for qs in QSPECS:
            assert fp.parse(qs.name) == qs

    def test_parse_rejects_garbage(self):
        with pytest.raises(ValueError):
            fp.parse("5.3")
        with pytest.raises(ValueError):
            fp.parse("Q53")

    def test_rejects_wide(self):
        with pytest.raises(ValueError):
            fp.QSpec(17, 15)  # W=32 is Rust-simulator-only

    def test_rejects_invalid(self):
        with pytest.raises(ValueError):
            fp.QSpec(0, 3)
        with pytest.raises(ValueError):
            fp.QSpec(4, -1)


class TestWrap:
    def test_identity_in_range(self):
        qs = fp.Q5_3
        for v in (-128, -1, 0, 1, 127):
            assert qs.wrap(v) == v

    def test_overflow_wraps(self):
        qs = fp.Q5_3
        assert qs.wrap(128) == -128  # two's-complement wraparound
        assert qs.wrap(-129) == 127
        assert qs.wrap(256) == 0

    def test_array_matches_scalar(self):
        qs = fp.Q9_7
        xs = np.array([-40000, -32768, -1, 0, 32767, 40000], np.int64)
        arr = np.asarray(qs.wrap(xs.astype(np.int32)))
        for x, a in zip(xs, arr):
            assert qs.wrap(int(x)) == int(a)


class TestArith:
    def test_add_basic(self):
        qs = fp.Q5_3
        # 1.0 + 1.5 = 2.5 in Q5.3: 8 + 12 = 20
        assert qs.add(qs.from_float(1.0), qs.from_float(1.5)) == 20

    def test_add_overflow_wraps(self):
        qs = fp.Q5_3
        assert qs.add(127, 1) == -128

    def test_mul_basic(self):
        qs = fp.Q5_3
        # 2.0 * 1.5 = 3.0 => raw 24
        assert qs.mul(qs.from_float(2.0), qs.from_float(1.5)) == 24

    def test_mul_truncates_toward_neg_inf(self):
        qs = fp.Q5_3
        # 0.125 * 0.125 = 0.015625 -> truncates to 0 (underflow, Fig. 6)
        assert qs.mul(1, 1) == 0
        # (-0.125) * 0.125 = -0.015625 -> arithmetic shift floors to -1 raw
        assert qs.mul(-1, 1) == -1

    def test_mul_overflow_wraps(self):
        qs = fp.Q5_3
        big = qs.from_float(15.0)  # 120
        # 15*15 = 225 -> wraps into 8-bit range (overflow, Fig. 6)
        assert qs.mul(big, big) == qs.wrap((120 * 120) >> 3)

    @given(st.data())
    @settings(max_examples=200, deadline=None)
    def test_scalar_matches_array(self, data):
        qs = data.draw(st.sampled_from(QSPECS))
        a = data.draw(raw_strategy(qs))
        b = data.draw(raw_strategy(qs))
        import jax.numpy as jnp
        aa, bb = jnp.int32(a), jnp.int32(b)
        assert qs.add(a, b) == int(np.asarray(qs.add(aa, bb)))
        assert qs.sub(a, b) == int(np.asarray(qs.sub(aa, bb)))
        assert qs.mul(a, b) == int(np.asarray(qs.mul(aa, bb)))

    @given(st.data())
    @settings(max_examples=200, deadline=None)
    def test_add_is_modular_sum(self, data):
        """Sequential wrapped adds == wrap of exact sum (ActGen soundness)."""
        qs = data.draw(st.sampled_from(QSPECS))
        xs = data.draw(st.lists(raw_strategy(qs), min_size=1, max_size=32))
        acc = 0
        for x in xs:
            acc = qs.add(acc, x)
        assert acc == qs.wrap(sum(xs))

    @given(st.data())
    @settings(max_examples=100, deadline=None)
    def test_results_in_range(self, data):
        qs = data.draw(st.sampled_from(QSPECS))
        a = data.draw(raw_strategy(qs))
        b = data.draw(raw_strategy(qs))
        for r in (qs.add(a, b), qs.sub(a, b), qs.mul(a, b)):
            assert qs.min_raw <= r <= qs.max_raw


class TestConversion:
    def test_from_float_saturates(self):
        qs = fp.Q5_3
        assert qs.from_float(1000.0) == 127
        assert qs.from_float(-1000.0) == -128

    def test_roundtrip_exact_values(self):
        qs = fp.Q5_3
        for v in (-16.0, -0.125, 0.0, 0.125, 1.0, 15.875):
            assert qs.to_float(qs.from_float(v)) == v

    def test_rounding(self):
        qs = fp.Q5_3  # resolution 0.125
        assert qs.from_float(0.0624) == 0
        assert qs.from_float(0.0626) == 1

    @given(st.floats(min_value=-20, max_value=20, allow_nan=False))
    @settings(max_examples=200, deadline=None)
    def test_quantization_error_bound(self, x):
        qs = fp.Q9_7
        if abs(x) < qs.to_float(qs.max_raw):
            err = abs(qs.to_float(qs.from_float(x)) - x)
            assert err <= 0.5 / qs.scale + 1e-12

    def test_array_conversion(self):
        qs = fp.Q5_3
        xs = np.array([-1000.0, -1.0, 0.06, 1000.0])
        raw = qs.from_float(xs)
        assert raw.dtype == np.int32
        assert list(raw) == [-128, -8, 0, 127]
