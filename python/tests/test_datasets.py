"""Synthetic dataset generator tests (determinism, structure, learnability)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import datasets as ds


class TestXorShift:
    def test_deterministic(self):
        a = ds.XorShift64Star(42)
        b = ds.XorShift64Star(42)
        assert [a.next_u64() for _ in range(10)] == [b.next_u64() for _ in range(10)]

    def test_seed_zero_survives(self):
        """seed|1 guards against the all-zero fixed point."""
        r = ds.XorShift64Star(0)
        assert r.next_u64() != 0

    def test_uniform_range(self):
        r = ds.XorShift64Star(7)
        xs = [r.uniform() for _ in range(1000)]
        assert all(0.0 <= x < 1.0 for x in xs)
        assert 0.4 < np.mean(xs) < 0.6

    def test_below_range(self):
        r = ds.XorShift64Star(9)
        assert all(0 <= r.below(10) < 10 for _ in range(200))

    def test_known_vector(self):
        """Pinned output — the Rust rng must produce the same stream."""
        r = ds.XorShift64Star(12345)
        vals = [r.next_u64() for _ in range(3)]
        r2 = ds.XorShift64Star(12345)
        assert vals == [r2.next_u64() for _ in range(3)]
        assert all(0 <= v < (1 << 64) for v in vals)


@pytest.mark.parametrize("name", ["smnist", "dvs", "shd"])
class TestGenerators:
    def test_shape_and_dtype(self, name):
        spikes, label = ds.SAMPLERS[name](0, "train", 12)
        assert spikes.shape == (12, ds.INFO[name]["inputs"])
        assert spikes.dtype == np.int32
        assert 0 <= label < ds.INFO[name]["classes"]

    def test_binary(self, name):
        spikes, _ = ds.SAMPLERS[name](3, "test", 10)
        assert set(np.unique(spikes)).issubset({0, 1})

    def test_deterministic(self, name):
        a, la = ds.SAMPLERS[name](17, "train", 10)
        b, lb = ds.SAMPLERS[name](17, "train", 10)
        assert la == lb and np.array_equal(a, b)

    def test_index_changes_sample(self, name):
        a, _ = ds.SAMPLERS[name](0, "train", 10)
        b, _ = ds.SAMPLERS[name](1, "train", 10)
        assert not np.array_equal(a, b)

    def test_split_changes_sample(self, name):
        a, _ = ds.SAMPLERS[name](0, "train", 10)
        b, _ = ds.SAMPLERS[name](0, "test", 10)
        assert not np.array_equal(a, b)

    def test_nonempty(self, name):
        spikes, _ = ds.SAMPLERS[name](5, "train", 20)
        assert spikes.sum() > 0

    def test_label_coverage(self, name):
        labels = {ds.SAMPLERS[name](i, "train", 1)[1] for i in range(120)}
        assert len(labels) == ds.INFO[name]["classes"]


class TestSmnistStructure:
    def test_digit8_superset_of_3_and_0(self):
        """Paper Fig. 11 confusion structure: 8 shares all segments of 3/0."""
        assert set(ds._SEGMENTS[3]) < set(ds._SEGMENTS[8])
        assert set(ds._SEGMENTS[0]) < set(ds._SEGMENTS[8])

    def test_distinct_digit_templates(self):
        assert len({ds._SEGMENTS[d] for d in range(10)}) == 10

    def test_image_range(self):
        rng = ds.XorShift64Star(5)
        img = ds.digit_image(8, rng)
        assert img.shape == (16, 16)
        assert (img >= 0).all() and (img <= 1).all()
        assert img.sum() > 0

    def test_rate_encoding_rate_scales(self):
        rng1, rng2 = ds.XorShift64Star(1), ds.XorShift64Star(1)
        img = np.full((4, 4), 1.0)
        low = ds.rate_encode(img, 200, rng1, max_rate=0.1).mean()
        high = ds.rate_encode(img, 200, rng2, max_rate=0.9).mean()
        assert high > low

    def test_rejects_bad_digit(self):
        with pytest.raises(ValueError):
            ds.digit_image(10, ds.XorShift64Star(1))

    def test_classes_are_separable_by_rate_profile(self):
        """Mean spatial profile of class a differs from class b (learnable)."""
        profs = {}
        for digit in (1, 8):
            acc = np.zeros(256)
            n = 0
            i = 0
            while n < 10:
                spikes, label = ds.smnist_sample(i, "train", 20)
                i += 1
                if label == digit:
                    acc += spikes.mean(axis=0)
                    n += 1
            profs[digit] = acc / n
        dist = np.abs(profs[1] - profs[8]).sum()
        assert dist > 1.0


class TestBatch:
    def test_batch_stacks(self):
        x, y = ds.batch("smnist", range(4), "train", 6)
        assert x.shape == (4, 6, 256) and y.shape == (4,)

    @given(st.integers(0, 1000), st.integers(1, 20))
    @settings(max_examples=10, deadline=None)
    def test_batch_matches_single(self, idx, t):
        x, y = ds.batch("smnist", [idx], "test", t)
        s, l = ds.smnist_sample(idx, "test", t)
        assert np.array_equal(x[0], s) and y[0] == l
