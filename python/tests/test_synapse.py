"""Connectivity (Eq. 9) and polarity (Eq. 10) tests."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import synapse as syn


class TestAllToAll:
    def test_full(self):
        m = syn.connection_mask(4, 3, syn.ALL_TO_ALL)
        assert m.shape == (4, 3) and (m == 1).all()

    def test_count(self):
        assert syn.synapse_count(256, 128, syn.ALL_TO_ALL) == 32768


class TestOneToOne:
    def test_identity(self):
        m = syn.connection_mask(5, 5, syn.ONE_TO_ONE)
        assert np.array_equal(m, np.eye(5, dtype=np.int32))

    def test_requires_square(self):
        with pytest.raises(ValueError):
            syn.connection_mask(4, 5, syn.ONE_TO_ONE)

    def test_count(self):
        assert syn.synapse_count(7, 7, syn.ONE_TO_ONE) == 7


class TestGaussian:
    def test_equal_width_tridiagonal(self):
        """Paper Eq. 9c: |i-j| <= 1 for equal-width layers, radius 1."""
        m = syn.connection_mask(6, 6, syn.GAUSSIAN, radius=1)
        expect = np.zeros((6, 6), np.int32)
        for i in range(6):
            for j in range(6):
                if abs(i - j) <= 1:
                    expect[i, j] = 1
        assert np.array_equal(m, expect)

    def test_radius_grows_window(self):
        m1 = syn.connection_mask(10, 10, syn.GAUSSIAN, radius=1)
        m2 = syn.connection_mask(10, 10, syn.GAUSSIAN, radius=2)
        assert m2.sum() > m1.sum()
        assert ((m2 - m1) >= 0).all()  # strictly a superset

    def test_unequal_width_receptive_field(self):
        """Downsampling layer: every post neuron sees a contiguous window."""
        m = syn.connection_mask(16, 4, syn.GAUSSIAN, radius=2)
        for j in range(4):
            idx = np.nonzero(m[:, j])[0]
            assert len(idx) > 0
            assert (np.diff(idx) == 1).all()  # contiguous

    def test_conv_filter_sizes(self):
        """Table V rows 2-3: 3x3 and 5x5 windows = radius 1 and 2 per-row taps."""
        m3 = syn.connection_mask(20, 20, syn.GAUSSIAN, radius=1)
        m5 = syn.connection_mask(20, 20, syn.GAUSSIAN, radius=2)
        # interior post-neurons see 3 resp. 5 pre-neurons
        assert m3[:, 10].sum() == 3
        assert m5[:, 10].sum() == 5

    def test_negative_radius_rejected(self):
        with pytest.raises(ValueError):
            syn.connection_mask(4, 4, syn.GAUSSIAN, radius=-1)


class TestValidation:
    def test_unknown_topology(self):
        with pytest.raises(ValueError):
            syn.connection_mask(4, 4, "smallworld")

    def test_bad_shape(self):
        with pytest.raises(ValueError):
            syn.connection_mask(0, 4, syn.ALL_TO_ALL)


class TestFoldWeights:
    def test_fold(self):
        omega = np.array([[1.0, 2.0]])
        alpha = np.array([[1, 0]])
        beta = np.array([[-1, 1]])
        w = syn.fold_weights(omega, alpha, beta)
        assert np.array_equal(w, np.array([[-1.0, 0.0]]))

    def test_polarity_validation(self):
        with pytest.raises(ValueError):
            syn.fold_weights(np.ones((1, 1)), np.ones((1, 1)), np.zeros((1, 1)))

    def test_alpha_validation(self):
        with pytest.raises(ValueError):
            syn.fold_weights(np.ones((1, 1)), 2 * np.ones((1, 1)), np.ones((1, 1)))

    def test_shape_validation(self):
        with pytest.raises(ValueError):
            syn.fold_weights(np.ones((1, 2)), np.ones((1, 1)), np.ones((1, 1)))

    @given(st.integers(1, 30), st.integers(1, 30), st.integers(0, 2**31 - 1))
    @settings(max_examples=40, deadline=None)
    def test_fold_sign_structure(self, m, n, seed):
        rng = np.random.default_rng(seed)
        omega = rng.normal(size=(m, n))
        alpha = rng.integers(0, 2, (m, n))
        beta = rng.choice([-1, 1], (m, n))
        w = syn.fold_weights(omega, alpha, beta)
        assert ((w == 0) | (np.sign(w) == beta)).all()
        assert (w[alpha == 0] == 0).all()
