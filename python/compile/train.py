"""Surrogate-gradient SNN training (the paper's "train in PyTorch" stage).

The paper trains each model in SNNTorch on a GPU workstation, then programs
the trained weights into QUANTISENC's synaptic memory. Here the training
framework is JAX (L2 of our stack — see DESIGN.md §1 substitution table);
everything downstream (quantization, register programming, inference) is
identical in spirit and bit-exact in the datapath.

Loss: softmax cross-entropy over output-layer spike counts (rate decoding,
exactly the paper's Fig.-11 spike-counter readout). Optimiser: hand-rolled
Adam (no optax in this image). The loss curve of every run is logged to
``artifacts/train_log_<dataset>.json`` and summarised in EXPERIMENTS.md.
"""

from __future__ import annotations

import functools
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets, model
from .fixedpoint import QSpec


# ---------------------------------------------------------------------------
# Hand-rolled Adam
# ---------------------------------------------------------------------------


def adam_init(params):
    zeros = [jnp.zeros_like(p) for p in params]
    return {"m": zeros, "v": [jnp.zeros_like(p) for p in params], "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = [b1 * m_ + (1 - b1) * g for m_, g in zip(state["m"], grads)]
    v = [b2 * v_ + (1 - b2) * g * g for v_, g in zip(state["v"], grads)]
    tf = t.astype(jnp.float32)
    mhat = [m_ / (1 - b1 ** tf) for m_ in m]
    vhat = [v_ / (1 - b2 ** tf) for v_ in v]
    new_params = [p - lr * mh / (jnp.sqrt(vh) + eps) for p, mh, vh in zip(params, mhat, vhat)]
    return new_params, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# Loss / step
# ---------------------------------------------------------------------------


def loss_fn(params, spikes, labels, spec, masks):
    counts = model.float_forward(spikes, params, spec)  # [B, n_out] spike counts
    logits = counts  # rate decoding: counts are the logits
    logp = jax.nn.log_softmax(logits)
    ce = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))
    return ce


@functools.partial(jax.jit, static_argnames=("spec",))
def train_step(params, opt_state, spikes, labels, spec, masks, lr):
    loss, grads = jax.value_and_grad(loss_fn)(params, spikes, labels, spec, masks)
    # Keep pruned (alpha=0) synapses pruned: they have no hardware storage.
    grads = [g * mk for g, mk in zip(grads, masks)]
    params, opt_state = adam_update(params, grads, opt_state, lr=lr)
    params = [p * mk for p, mk in zip(params, masks)]
    return params, opt_state, loss


@functools.partial(jax.jit, static_argnames=("spec",))
def eval_batch(params, spikes, labels, spec):
    counts = model.float_forward(spikes, params, spec)
    return jnp.mean((jnp.argmax(counts, axis=1) == labels).astype(jnp.float32))


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def train(dataset: str, spec: model.ModelSpec, steps: int = 300, batch_size: int = 32,
          t_steps: int = 40, lr: float = 2e-3, n_train: int = 2048, n_test: int = 256,
          seed: int = 0, log_path: str | None = None, verbose: bool = True):
    """Train a float SNN; returns (float_params, history dict)."""
    info = datasets.INFO[dataset]
    assert spec.sizes[0] == info["inputs"] and spec.sizes[-1] == info["classes"], \
        f"spec {spec.name} does not match dataset {dataset}"

    t0 = time.time()
    if verbose:
        print(f"[train] generating {n_train}+{n_test} synthetic {dataset} samples ...")
    train_x, train_y = datasets.batch(dataset, range(n_train), "train", t_steps)
    test_x, test_y = datasets.batch(dataset, range(n_test), "test", t_steps)
    if verbose:
        print(f"[train] data ready in {time.time()-t0:.1f}s "
              f"(mean rate {train_x.mean():.4f} spikes/step/input)")

    key = jax.random.PRNGKey(seed)
    params = model.init_params(spec, key)
    masks = [jnp.asarray(l.mask(), jnp.float32) for l in spec.layers]
    opt_state = adam_init(params)

    train_x = jnp.asarray(train_x, jnp.float32)
    train_y = jnp.asarray(train_y)
    rng = np.random.default_rng(seed)
    history = {"loss": [], "step": [], "eval_acc": [], "eval_step": []}

    for step in range(steps):
        idx = rng.integers(0, n_train, batch_size)
        params, opt_state, loss = train_step(
            params, opt_state, train_x[idx], train_y[idx], spec, masks, lr)
        history["loss"].append(float(loss))
        history["step"].append(step)
        if verbose and (step % 50 == 0 or step == steps - 1):
            print(f"[train] {dataset} step {step:4d} loss {float(loss):.4f}")
        if step % 100 == 99 or step == steps - 1:
            acc = _eval(params, test_x, test_y, spec)
            history["eval_acc"].append(acc)
            history["eval_step"].append(step)
            if verbose:
                print(f"[train] {dataset} step {step:4d} test acc {acc*100:.1f}%")

    history["train_seconds"] = time.time() - t0
    history["final_acc"] = history["eval_acc"][-1]
    if log_path:
        with open(log_path, "w") as f:
            json.dump({"dataset": dataset, "spec": spec.name, "steps": steps,
                       "batch_size": batch_size, "t_steps": t_steps, **history}, f)
    return params, history


def _eval(params, test_x, test_y, spec, chunk: int = 64) -> float:
    accs, n = [], test_x.shape[0]
    for i in range(0, n, chunk):
        xb = jnp.asarray(test_x[i:i + chunk], jnp.float32)
        yb = jnp.asarray(test_y[i:i + chunk])
        accs.append(float(eval_batch(params, xb, yb, spec)) * xb.shape[0])
    return sum(accs) / n


def quantized_accuracy(params, spec: model.ModelSpec, dataset: str, n_test: int = 100,
                       t_steps: int = 40, reset_mode=None, growth=None, refractory=None):
    """Hardware-datapath accuracy (Table VIII / X): quantize then run Qn.q ref."""
    from .kernels import ref as R
    qw = model.quantize_params(params, spec)
    kwargs = {}
    if reset_mode is not None:
        kwargs["reset_mode"] = reset_mode
    if growth is not None:
        kwargs["growth"] = growth
    if refractory is not None:
        kwargs["refractory"] = refractory
    regs = model.default_regs(spec, **kwargs)
    test_x, test_y = datasets.batch(dataset, range(n_test), "test", t_steps)

    fwd = jax.jit(lambda s: model.quantized_forward(
        s, [jnp.asarray(w) for w in qw], jnp.asarray(regs), spec, use_kernel=False)["counts"])
    correct = 0
    spikes_total = 0
    for i in range(n_test):
        counts = np.asarray(fwd(jnp.asarray(test_x[i])))
        correct += int(np.argmax(counts) == test_y[i])
    return correct / n_test
