"""Signed Qn.q fixed-point arithmetic — the bit-exact semantics of QUANTISENC.

This module is the single source of truth for the paper's Section III-C
("Signed Neuronal Computations", Fig. 6) on the Python side. The Rust
substrate (`rust/src/fixed/`) implements the identical semantics; the two are
cross-checked bit-exactly via golden vectors emitted by `aot.py` and via the
HLO-executed model vs the Rust cycle-accurate simulator.

Representation
--------------
A Qn.q number has W = n + q bits total (the sign bit is part of the n integer
bits, as in the paper: Q5.3 is an 8-bit quantity). Values are stored
sign-extended in int32. All datapath arithmetic *wraps* modulo 2^W (two's
complement), exactly like the HDL registers:

  * add/sub: integer add/sub, then wrap to W bits.
  * mul (Fig. 6): full (2W-bit) product, arithmetic-shift-right by q
    (truncation toward -inf — discarded LSBs are the paper's "underflow"),
    then wrap to W bits (discarded MSBs are the paper's "overflow").

Because we restrict the emulated datapath to W <= 16, the full product of two
W-bit operands fits in int32 (|a|,|b| <= 2^15 => |a*b| <= 2^30), so no int64
is needed anywhere. W = 32 (Q17.15) configurations are evaluated through the
Rust simulator only (documented in DESIGN.md §2).

Conversion from float *saturates* (it models the one-time software-side
quantization of trained weights / register values); datapath ops *wrap*
(they model silicon).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class QSpec:
    """Static quantization configuration (paper Table I: static, HDL params)."""

    n: int  # integer bits, sign included (paper's Qn.q)
    q: int  # fraction bits

    def __post_init__(self) -> None:
        if self.n < 1 or self.q < 0:
            raise ValueError(f"invalid QSpec Q{self.n}.{self.q}")
        if self.width > 16:
            raise ValueError(
                f"Q{self.n}.{self.q}: emulated datapath supports W<=16 "
                "(W=32 runs through the Rust simulator only)"
            )

    @property
    def width(self) -> int:
        return self.n + self.q

    @property
    def scale(self) -> int:
        return 1 << self.q

    @property
    def max_raw(self) -> int:
        return (1 << (self.width - 1)) - 1

    @property
    def min_raw(self) -> int:
        return -(1 << (self.width - 1))

    @property
    def name(self) -> str:
        return f"Q{self.n}.{self.q}"

    # -- raw (int) domain ---------------------------------------------------

    def wrap(self, x):
        """Wrap an integer (array) to W-bit two's complement, sign-extended."""
        half = 1 << (self.width - 1)
        mask = (1 << self.width) - 1
        if isinstance(x, (int, np.integer)):
            return int(((int(x) + half) & mask) - half)
        x = jnp.asarray(x, jnp.int32)
        return ((x + half) & mask) - half

    def add(self, a, b):
        """Wrapping fixed-point add (same rules as integer add, Fig. 6 text)."""
        if isinstance(a, (int, np.integer)) and isinstance(b, (int, np.integer)):
            return self.wrap(int(a) + int(b))
        return self.wrap(jnp.asarray(a, jnp.int32) + jnp.asarray(b, jnp.int32))

    def sub(self, a, b):
        if isinstance(a, (int, np.integer)) and isinstance(b, (int, np.integer)):
            return self.wrap(int(a) - int(b))
        return self.wrap(jnp.asarray(a, jnp.int32) - jnp.asarray(b, jnp.int32))

    def mul(self, a, b):
        """Fig. 6 multiply: full product >> q (arithmetic), wrap to W bits."""
        if isinstance(a, (int, np.integer)) and isinstance(b, (int, np.integer)):
            return self.wrap((int(a) * int(b)) >> self.q)
        prod = jnp.asarray(a, jnp.int32) * jnp.asarray(b, jnp.int32)
        return self.wrap(jnp.right_shift(prod, self.q))

    # -- float <-> raw ------------------------------------------------------

    def from_float(self, x):
        """Saturating float -> Qn.q raw (software-side quantization)."""
        if isinstance(x, (float, int, np.floating, np.integer)):
            raw = int(np.floor(float(x) * self.scale + 0.5))
            return int(np.clip(raw, self.min_raw, self.max_raw))
        raw = np.floor(np.asarray(x, np.float64) * self.scale + 0.5)
        return np.clip(raw, self.min_raw, self.max_raw).astype(np.int32)

    def to_float(self, raw):
        if isinstance(raw, (int, np.integer)):
            return float(raw) / self.scale
        return np.asarray(raw, np.float64) / self.scale


# The paper's evaluated settings (Table IV); Q17.15 is Rust-simulator-only.
Q2_2 = QSpec(2, 2)
Q3_1 = QSpec(3, 1)
Q5_3 = QSpec(5, 3)
Q9_7 = QSpec(9, 7)

BY_NAME = {s.name: s for s in (Q2_2, Q3_1, Q5_3, Q9_7)}


def parse(name: str) -> QSpec:
    """Parse 'Q5.3' style names."""
    if not name.startswith("Q") or "." not in name:
        raise ValueError(f"bad QSpec name {name!r}")
    n, q = name[1:].split(".")
    return QSpec(int(n), int(q))
