"""AOT pipeline: train → quantize → lower to HLO text → artifacts/.

This is the entire build-time Python path of the three-layer stack. It runs
once under ``make artifacts`` and produces everything the self-contained Rust
binary needs on the request path:

  artifacts/
    manifest.json              — index of all artifacts (shapes, dtypes, T…)
    <ds>_<Q>.hlo.txt           — quantized T-step forward, per dataset config.
                                 Parameters: (spikes [T,N_in] i32,
                                 W_1..W_K i32, regs [6] i32) →
                                 (counts [n_out], layer_spike_totals [K]) —
                                 weights/regs are runtime inputs so the Rust
                                 coordinator can program them (wt_in/cfg_in).
    lif_step_<Q>.hlo.txt       — single-layer single-step kernel (256→128),
                                 used by bench_runtime and the HLO↔hdl
                                 bit-exactness integration test.
    weights_<ds>_<Q>.bin       — trained quantized weights, flat i32 LE.
    weights_<ds>_float.bin     — float32 weights (software-reference path).
    golden_*.json              — golden vectors for Rust bit-exactness tests
                                 (fixed-point ops, LIF traces, dataset spikes).
    train_log_<ds>.json        — loss curves (EXPERIMENTS.md e2e record).

Interchange format is **HLO text**, never serialized protos: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the `xla` 0.1.6 crate) rejects; the text parser reassigns ids
(see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import datasets, model, train
from .fixedpoint import Q3_1, Q5_3, Q9_7, QSpec
from .kernels import lif, ref

# Dataset -> (ModelSpec sizes, training budget). Sizes follow paper Table XI;
# smnist is the paper's baseline 256x128x10.
CONFIGS = {
    "smnist": dict(sizes=(256, 128, 10), steps=400, n_train=2048, n_test=256),
    "dvs": dict(sizes=(400, 300, 300, 11), steps=300, n_train=1024, n_test=160),
    "shd": dict(sizes=(700, 256, 256, 20), steps=300, n_train=1024, n_test=160),
}
T_STEPS = 40  # deployment sequence length baked into the HLO artifacts
DEPLOY_QSPECS = {"smnist": (Q9_7, Q5_3, Q3_1), "dvs": (Q5_3,), "shd": (Q5_3,)}

# Deployment pre-scaling (power of two) per quantization: weights and vth
# are scaled together before rounding, using the Qn.q range fully (see
# model.quantize_params). Chosen empirically on the validation split —
# see EXPERIMENTS.md Table VIII notes.
DEPLOY_SCALE = {"Q9.7": 4.0, "Q5.3": 4.0, "Q3.1": 2.0}
# Quantizations that get a quantization-aware fine-tune (STE fake-quant)
# before deployment — needed where the plain rounding SNR collapses.
QAT_STEPS = {"Q3.1": 400}


def qat_finetune(params, spec, qspec, scale, dataset, steps, t_steps,
                 n_train=1024, lr=1e-3, seed=0):
    """Quantization-aware fine-tune: fake-quantized weights (straight-
    through estimator) inside the float surrogate-gradient model, with the
    deployment threshold. Returns fine-tuned float params."""

    @jax.custom_vjp
    def fake_quant(w):
        raw = jnp.clip(jnp.floor(w * scale * qspec.scale + 0.5),
                       qspec.min_raw, qspec.max_raw)
        return raw / (scale * qspec.scale)

    fake_quant.defvjp(lambda w: (fake_quant(w), None), lambda _, g: (g,))

    vth_deploy = min(scale * 1.0, qspec.to_float(qspec.max_raw))
    fp = dict(vth=vth_deploy / scale)

    train_x, train_y = datasets.batch(dataset, range(n_train), "train", t_steps)
    train_x = jnp.asarray(train_x, jnp.float32)
    train_y = jnp.asarray(train_y)

    def loss_fn(ps, x, y):
        qp = [fake_quant(p) for p in ps]
        counts = model.float_forward(x, qp, spec, params=fp)
        logp = jax.nn.log_softmax(counts)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))

    @jax.jit
    def step(ps, opt, x, y):
        loss, grads = jax.value_and_grad(loss_fn)(ps, x, y)
        ps, opt = train.adam_update(ps, grads, opt, lr=lr)
        return ps, opt, loss

    opt = train.adam_init(params)
    rng = np.random.default_rng(seed)
    for i in range(steps):
        idx = rng.integers(0, n_train, 32)
        params, opt, loss = step(params, opt, train_x[idx], train_y[idx])
        if i % 100 == 99:
            print(f"[aot]   qat {qspec.name} step {i + 1} loss {float(loss):.4f}")
    return params


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_forward(spec: model.ModelSpec, t_steps: int) -> str:
    """Lower the quantized T-step forward with weights+regs as parameters."""

    def fwd(spikes, *wr):
        weights, regs = list(wr[:-1]), wr[-1]
        out = model.quantized_forward(spikes, weights, regs, spec, use_kernel=True)
        return out["counts"], out["layer_spike_totals"]

    args = [jax.ShapeDtypeStruct((t_steps, spec.sizes[0]), jnp.int32)]
    args += [jax.ShapeDtypeStruct((l.fan_in, l.neurons), jnp.int32) for l in spec.layers]
    args += [jax.ShapeDtypeStruct((ref.NUM_REGS,), jnp.int32)]
    return to_hlo_text(jax.jit(fwd).lower(*args))


def lower_lif_step(qspec: QSpec, m: int = 256, n: int = 128) -> str:
    """Lower one Pallas LIF layer step (micro-bench + bit-exactness probe)."""

    def step(spikes, w, vmem, refcnt, regs):
        return lif.lif_layer_step(spikes, w, vmem, refcnt, regs, qspec=qspec)

    args = [
        jax.ShapeDtypeStruct((m,), jnp.int32),
        jax.ShapeDtypeStruct((m, n), jnp.int32),
        jax.ShapeDtypeStruct((n,), jnp.int32),
        jax.ShapeDtypeStruct((n,), jnp.int32),
        jax.ShapeDtypeStruct((ref.NUM_REGS,), jnp.int32),
    ]
    return to_hlo_text(jax.jit(step).lower(*args))


# ---------------------------------------------------------------------------
# Golden vectors (Rust bit-exactness)
# ---------------------------------------------------------------------------


def golden_fixedpoint() -> dict:
    """Exhaustive-ish Qn.q op vectors for rust/src/fixed tests."""
    rng = datasets.XorShift64Star(0xF1DE)
    cases = []
    for qname in ("Q2.2", "Q3.1", "Q5.3", "Q9.7"):
        from . import fixedpoint as fp
        qs = fp.parse(qname)
        for _ in range(64):
            a = rng.below(1 << qs.width) - (1 << (qs.width - 1))
            b = rng.below(1 << qs.width) - (1 << (qs.width - 1))
            cases.append({
                "q": qname, "a": a, "b": b,
                "add": qs.add(a, b), "sub": qs.sub(a, b), "mul": qs.mul(a, b),
            })
    return {"cases": cases}


def golden_lif_trace(qspec: QSpec, t_steps: int = 32) -> dict:
    """A deterministic multi-step single-layer trace for hdl/neuron.rs."""
    rng = datasets.XorShift64Star(0x11F0 + qspec.width)
    m, n = 12, 5
    w = np.array([[rng.below(1 << qspec.width) - (1 << (qspec.width - 1))
                   for _ in range(n)] for _ in range(m)], np.int32)
    spikes = np.array([[1 if rng.uniform() < 0.35 else 0 for _ in range(m)]
                       for _ in range(t_steps)], np.int32)
    traces = {}
    for mode in (ref.RESET_DEFAULT, ref.RESET_TO_ZERO, ref.RESET_BY_SUBTRACTION,
                 ref.RESET_TO_CONSTANT):
        regs = np.array([qspec.from_float(0.2), qspec.from_float(1.0),
                         qspec.from_float(1.0), qspec.from_float(0.25),
                         mode, 2], np.int32)
        vmem = np.zeros(n, np.int32)
        refc = np.zeros(n, np.int32)
        spk_t, vm_t = [], []
        for t in range(t_steps):
            s, vmem, refc = (np.asarray(x) for x in ref.lif_layer_step_ref(
                spikes[t], w, vmem, refc, regs, qspec))
            spk_t.append(s.tolist())
            vm_t.append(vmem.tolist())
        traces[str(mode)] = {"regs": regs.tolist(), "spikes_out": spk_t, "vmem": vm_t}
    return {
        "q": qspec.name, "m": m, "n": n,
        "weights": w.tolist(), "spikes_in": spikes.tolist(), "traces": traces,
    }


def golden_datasets() -> dict:
    """First samples of each dataset for rust/src/datasets parity tests."""
    out = {}
    for name in ("smnist", "dvs", "shd"):
        spikes, label = datasets.SAMPLERS[name](0, "test", 8)
        out[name] = {
            "label": int(label),
            "t": 8,
            "nnz": int(spikes.sum()),
            "spike_rows": [int(r) for r in spikes.sum(axis=1)],
            "first_row_indices": np.nonzero(spikes[0])[0].tolist(),
        }
    return out


# ---------------------------------------------------------------------------
# Main build
# ---------------------------------------------------------------------------


def build(out_dir: str, quick: bool = False, dataset_filter=None) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"t_steps": T_STEPS, "models": {}, "kernels": {}, "built_unix": int(time.time())}

    # Golden vectors first (cheap, no training needed).
    for fname, payload in (
        ("golden_fixedpoint.json", golden_fixedpoint()),
        ("golden_lif_q53.json", golden_lif_trace(Q5_3)),
        ("golden_lif_q97.json", golden_lif_trace(Q9_7)),
        ("golden_datasets.json", golden_datasets()),
    ):
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(payload, f)
        print(f"[aot] wrote {fname}")

    # Single-step kernels.
    for qs in (Q5_3, Q9_7):
        name = f"lif_step_{qs.name.replace('.', '')}"
        text = lower_lif_step(qs)
        with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
            f.write(text)
        manifest["kernels"][name] = {
            "q": qs.name, "m": 256, "n": 128,
            "file": f"{name}.hlo.txt",
        }
        print(f"[aot] wrote {name}.hlo.txt ({len(text)} chars)")

    # Train + lower per dataset.
    names = dataset_filter or list(CONFIGS)
    for ds in names:
        cfg = CONFIGS[ds]
        steps = 60 if quick else cfg["steps"]
        n_train = 256 if quick else cfg["n_train"]
        n_test = 64 if quick else cfg["n_test"]
        spec_f = model.ModelSpec(tuple(cfg["sizes"]), Q5_3)  # qspec irrelevant for float
        params, hist = train.train(
            ds, spec_f, steps=steps, n_train=n_train, n_test=n_test, t_steps=T_STEPS,
            log_path=os.path.join(out_dir, f"train_log_{ds}.json"))

        # Float weights (software reference).
        flat = np.concatenate([np.asarray(p, np.float32).reshape(-1) for p in params])
        flat.tofile(os.path.join(out_dir, f"weights_{ds}_float.bin"))

        entry = {
            "sizes": list(cfg["sizes"]), "t_steps": T_STEPS,
            "float_acc": hist["final_acc"], "variants": {},
        }
        for qs in DEPLOY_QSPECS[ds]:
            spec = model.ModelSpec(tuple(cfg["sizes"]), qs)
            scale = DEPLOY_SCALE.get(qs.name, 1.0)
            deploy_params = params
            qat_steps = QAT_STEPS.get(qs.name, 0)
            if qat_steps and not quick:
                print(f"[aot] qat fine-tune {ds} {qs.name} (scale {scale}) ...")
                deploy_params = qat_finetune(
                    params, spec, qs, scale, ds, qat_steps, T_STEPS)
            qw = model.quantize_params(deploy_params, spec, scale=scale)
            qflat = np.concatenate([w.reshape(-1) for w in qw]).astype(np.int32)
            qtag = qs.name.replace(".", "")
            qflat.tofile(os.path.join(out_dir, f"weights_{ds}_{qtag}.bin"))
            hlo = lower_forward(spec, T_STEPS)
            hlo_file = f"{ds}_{qtag}.hlo.txt"
            with open(os.path.join(out_dir, hlo_file), "w") as f:
                f.write(hlo)
            vth_deploy = min(scale * 1.0, qs.to_float(qs.max_raw))
            regs = model.default_regs(spec, vth=vth_deploy)
            entry["variants"][qs.name] = {
                "hlo": hlo_file,
                "weights": f"weights_{ds}_{qtag}.bin",
                "default_regs": regs.tolist(),
                "layer_shapes": [[l.fan_in, l.neurons] for l in spec.layers],
                "scale": scale,
            }
            print(f"[aot] wrote {hlo_file} ({len(hlo)} chars)")
        manifest["models"][ds] = entry

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest.json written — artifacts complete in {out_dir}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output dir (or dir of --out file)")
    ap.add_argument("--quick", action="store_true", help="small training budget (CI)")
    ap.add_argument("--datasets", nargs="*", default=None)
    args = ap.parse_args()
    out = args.out
    if out.endswith(".hlo.txt"):  # Makefile passes the sentinel file path
        out = os.path.dirname(out)
    build(out, quick=args.quick, dataset_filter=args.datasets)


if __name__ == "__main__":
    main()
