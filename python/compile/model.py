"""L2 — the QUANTISENC SNN model in JAX.

Defines the K-layer feed-forward spiking network of paper Fig. 1: layer k
receives the spike train of layer k-1 through its local synaptic memory and
produces an output spike train. Two variants share one structure:

  * ``quantized_forward`` — the deployment path: bit-exact Qn.q datapath via
    the L1 Pallas kernel (`kernels.lif`), scanned over T timesteps. This is
    what `aot.py` lowers to HLO for the Rust runtime; weights and the
    control-register vector are *parameters* of the lowered computation so
    the Rust coordinator can program them at run time (the paper's wt_in /
    cfg_in interfaces).

  * ``float_forward`` — the training path ("SNNTorch software" analogue):
    float32 LIF with a fast-sigmoid surrogate gradient on the spike
    nonlinearity, used by `train.py` and as the software baseline for
    Fig. 12 / Table VIII.

State per layer is (vmem, refcnt); the scan carries the tuple of all layers,
giving the same layer-by-layer dataflow as the hardware (spikes produced by
layer k at timestep t feed layer k+1 *within* the same timestep, matching the
paper's dataflow processing of one input stream).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .fixedpoint import QSpec
from .kernels import lif, ref
from .kernels import synapse as syn


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """Static configuration of one hardware layer (paper Table I)."""

    fan_in: int
    neurons: int
    topology: str = syn.ALL_TO_ALL
    radius: int = 1

    def mask(self) -> np.ndarray:
        return syn.connection_mask(self.fan_in, self.neurons, self.topology, self.radius)

    @property
    def synapses(self) -> int:
        return syn.synapse_count(self.fan_in, self.neurons, self.topology, self.radius)


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """A full QUANTISENC core configuration, e.g. 256x128x10."""

    sizes: tuple  # (n_in, n_1, ..., n_out)
    qspec: QSpec
    topologies: tuple = ()  # per layer; default all-to-all

    def __post_init__(self):
        if len(self.sizes) < 2:
            raise ValueError("need at least input + one layer")
        if self.topologies and len(self.topologies) != self.num_layers:
            raise ValueError("topologies must match layer count")

    @property
    def num_layers(self) -> int:
        return len(self.sizes) - 1

    @property
    def layers(self) -> Sequence[LayerSpec]:
        topos = self.topologies or tuple(syn.ALL_TO_ALL for _ in range(self.num_layers))
        return tuple(
            LayerSpec(self.sizes[i], self.sizes[i + 1], topos[i])
            for i in range(self.num_layers)
        )

    @property
    def total_neurons(self) -> int:
        # The paper counts input-layer units as neurons too (394 = 256+128+10).
        return int(sum(self.sizes))

    @property
    def total_synapses(self) -> int:
        return int(sum(l.synapses for l in self.layers))

    @property
    def name(self) -> str:
        return "x".join(str(s) for s in self.sizes)


# ---------------------------------------------------------------------------
# Parameter initialisation / quantization
# ---------------------------------------------------------------------------


def init_params(spec: ModelSpec, key) -> list:
    """He-style signed init, masked by per-layer alpha. Float32 leaves."""
    params = []
    for layer in spec.layers:
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (layer.fan_in, layer.neurons), jnp.float32)
        w = w * jnp.sqrt(2.0 / layer.fan_in)
        params.append(w * jnp.asarray(layer.mask(), jnp.float32))
    return params


def quantize_params(params, spec: ModelSpec, scale: float = 1.0) -> list:
    """Saturating float -> Qn.q raw int32 weights (software-side, once).

    ``scale`` implements deployment pre-scaling: weights (and, by the
    caller, vth/vreset) are multiplied by a power of two before rounding so
    the trained weights use the available Qn.q resolution. Scaling weights
    and threshold together leaves the float dynamics invariant but shrinks
    quantization error — it is just a different wt_in/cfg_in programming of
    the same hardware.
    """
    return [np.asarray(spec.qspec.from_float(np.asarray(w) * scale), np.int32) for w in params]


def default_regs(spec: ModelSpec, vth: float = 1.0, decay: float = 0.2,
                 growth: float = 1.0, reset_mode: int = ref.RESET_BY_SUBTRACTION,
                 refractory: int = 0, vreset: float = 0.0) -> np.ndarray:
    """Control-register vector in Qn.q raw units (paper Table I dynamic row)."""
    qs = spec.qspec
    return np.array(
        [qs.from_float(decay), qs.from_float(growth), qs.from_float(vth),
         qs.from_float(vreset), reset_mode, refractory],
        dtype=np.int32,
    )


FLOAT_PARAMS = dict(decay=0.2, growth=1.0, vth=1.0, vreset=0.0,
                    reset_mode=ref.RESET_BY_SUBTRACTION, refractory=0)


# ---------------------------------------------------------------------------
# Quantized deployment forward (uses the Pallas kernel)
# ---------------------------------------------------------------------------


def quantized_forward(spikes, weights, regs, spec: ModelSpec, use_kernel: bool = True):
    """Run T timesteps of the quantized core.

    Args:
      spikes:  [T, n_in] int32 — input spike train (AER-decoded).
      weights: list of [M_k, N_k] int32 Qn.q raw weights.
      regs:    [NUM_REGS] int32 — shared control registers (the hardware has
               one decoder per core; per-layer registers are a Rust-side
               extension, see coordinator/interface.rs).
      use_kernel: Pallas kernel (True) or pure-jnp ref (False) — both
               bit-exact; the ref path cross-validates the kernel inside jit.

    Returns dict with:
      out_spikes [T, n_out], counts [n_out], layer_spike_totals [K] (drives
      the activity/power model), final vmem per layer.
    """
    qs = spec.qspec
    step_fn = (lambda s, w, v, r, g: lif.lif_layer_step(s, w, v, r, g, qspec=qs)) \
        if use_kernel else (lambda s, w, v, r, g: ref.lif_layer_step_ref(s, w, v, r, g, qs))

    vmems = tuple(jnp.zeros((l.neurons,), jnp.int32) for l in spec.layers)
    refs = tuple(jnp.zeros((l.neurons,), jnp.int32) for l in spec.layers)
    totals = tuple(jnp.zeros((), jnp.int32) for _ in spec.layers)

    def step(carry, spk_in):
        vmems, refs, totals = carry
        new_v, new_r, new_t = [], [], []
        out = spk_in
        for k in range(spec.num_layers):
            out, v, r = step_fn(out, weights[k], vmems[k], refs[k], regs)
            new_v.append(v)
            new_r.append(r)
            new_t.append(totals[k] + jnp.sum(out))
        return (tuple(new_v), tuple(new_r), tuple(new_t)), out

    (vmems, refs, totals), out_spikes = jax.lax.scan(step, (vmems, refs, totals), spikes)
    return {
        "out_spikes": out_spikes,
        "counts": jnp.sum(out_spikes, axis=0),
        "layer_spike_totals": jnp.stack(totals),
        "final_vmem": vmems,
    }


# ---------------------------------------------------------------------------
# Float training forward (surrogate gradient)
# ---------------------------------------------------------------------------


@jax.custom_vjp
def spike_surrogate(v_minus_th):
    """Heaviside with fast-sigmoid surrogate gradient (SNNTorch-style)."""
    return (v_minus_th >= 0.0).astype(jnp.float32)


def _spk_fwd(x):
    return spike_surrogate(x), x


def _spk_bwd(x, g):
    # d/dx fast-sigmoid: 1 / (1 + k|x|)^2 with slope k=10.
    k = 10.0
    return (g / (1.0 + k * jnp.abs(x)) ** 2,)


spike_surrogate.defvjp(_spk_fwd, _spk_bwd)


def float_forward(spikes, weights, spec: ModelSpec, params=None):
    """Training/software forward: [B?, T, n_in] float spikes -> spike counts.

    Uses reset-by-subtraction (the paper's baseline, Table X col 7) in a
    differentiable form: v' = v_dyn - spike * vth.
    """
    p = dict(FLOAT_PARAMS)
    if params:
        p.update(params)

    batched = spikes.ndim == 3

    def single(spk_seq):
        vmems = tuple(jnp.zeros((l.neurons,), jnp.float32) for l in spec.layers)

        def step(vmems, spk_in):
            out = spk_in
            new_v = []
            for k in range(spec.num_layers):
                act = jnp.dot(out, weights[k])
                v = vmems[k] - p["decay"] * vmems[k] + p["growth"] * act
                s = spike_surrogate(v - p["vth"])
                v = v - s * p["vth"]  # reset-by-subtraction, differentiable
                new_v.append(v)
                out = s
            return tuple(new_v), out

        _, out_spikes = jax.lax.scan(step, vmems, spk_seq)
        return jnp.sum(out_spikes, axis=0)  # spike counts = rate logits

    return jax.vmap(single)(spikes) if batched else single(spikes)


def float_membrane_trace(spikes, weights, spec: ModelSpec, layer: int, params=None):
    """Per-timestep vmem of one layer (float) — Fig. 12's software trace."""
    p = dict(FLOAT_PARAMS)
    if params:
        p.update(params)

    vmems = tuple(jnp.zeros((l.neurons,), jnp.float32) for l in spec.layers)

    def step(vmems, spk_in):
        out = spk_in
        new_v = []
        for k in range(spec.num_layers):
            act = jnp.dot(out, weights[k])
            v = vmems[k] - p["decay"] * vmems[k] + p["growth"] * act
            s = (v >= p["vth"]).astype(jnp.float32)
            v = v - s * p["vth"]
            new_v.append(v)
            out = s
        return tuple(new_v), new_v[layer]

    _, trace = jax.lax.scan(step, vmems, spikes)
    return trace


def quantized_membrane_trace(spikes, weights, regs, spec: ModelSpec, layer: int):
    """Per-timestep vmem (raw Qn.q) of one layer — Fig. 12's hardware trace."""
    qs = spec.qspec
    vmems = tuple(jnp.zeros((l.neurons,), jnp.int32) for l in spec.layers)
    refs = tuple(jnp.zeros((l.neurons,), jnp.int32) for l in spec.layers)

    def step(carry, spk_in):
        vmems, refs = carry
        out = spk_in
        new_v, new_r = [], []
        for k in range(spec.num_layers):
            out, v, r = ref.lif_layer_step_ref(out, weights[k], vmems[k], refs[k], regs, qs)
            new_v.append(v)
            new_r.append(r)
        return (tuple(new_v), tuple(new_r)), new_v[layer]

    _, trace = jax.lax.scan(step, (vmems, refs), spikes)
    return trace
