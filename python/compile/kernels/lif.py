"""Pallas kernel for one quantized LIF layer timestep (the L1 hot-spot).

This is the TPU-shaped restatement of the paper's per-layer hardware
(DESIGN.md §2 Hardware-Adaptation): the layer's weight matrix — the paper's
*distributed synaptic memory*, which the FPGA keeps in BRAM inside the layer
— stays resident in VMEM as a kernel operand block, and the spike vector
streams through it. ActGen's M-cycle serial accumulate becomes a single
int32 reduction feeding the MXU-friendly dot; VmemDyn/VmemSel/SpkGen are
vectorised lanes over the layer's N neurons.

The kernel is tiled over neurons: grid = ceil(N / block_n), with BlockSpec
carving [M, block_n] weight tiles — this is the HBM↔VMEM schedule the paper
expressed with its BRAM organisation. Lowered with ``interpret=True``
(CPU PJRT; real-TPU lowering emits a Mosaic custom-call the CPU plugin
cannot execute — see /opt/xla-example/README.md).

Semantics are bit-identical to ``ref.lif_layer_step_ref`` (pytest +
hypothesis enforce this across shapes, Qn.q settings, and register values).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..fixedpoint import QSpec
from . import ref as R

# Default neuron tile. All paper configurations (N <= 1470) use a handful of
# tiles; 128 matches the paper's own FC-128 granularity and lines up with
# TPU lane width.
DEFAULT_BLOCK_N = 128


def _wrap(x, width: int):
    half = 1 << (width - 1)
    mask = (1 << width) - 1
    return ((x + half) & mask) - half


def _lif_kernel(spk_ref, w_ref, vmem_ref, ref_ref, regs_ref,
                spk_out_ref, vmem_out_ref, refcnt_out_ref, *, qspec: QSpec):
    """One [M, block_n] tile: ActGen + VmemDyn + SpkGen + VmemSel."""
    width = qspec.width
    q = qspec.q

    decay = regs_ref[R.REG_DECAY]
    growth = regs_ref[R.REG_GROWTH]
    vth = regs_ref[R.REG_VTH]
    vreset = regs_ref[R.REG_VRESET]
    mode = regs_ref[R.REG_RESET_MODE]
    refractory = regs_ref[R.REG_REFRACTORY]

    spikes = spk_ref[...]          # [M]  int32 in {0,1}
    weights = w_ref[...]           # [M, block_n] int32 (Qn.q raw)
    vmem = vmem_ref[...]           # [block_n]
    refcnt = ref_ref[...]          # [block_n]

    # ActGen: weighted sum of input spikes; wrapping accumulate (Eq. 6).
    act = _wrap(jnp.dot(spikes, weights, preferred_element_type=jnp.int32), width)

    # VmemDyn (Eq. 3): v - decay*v + growth*act, Fig.-6 fixed-point multiply.
    dv = _wrap(jnp.right_shift(decay * vmem, q), width)
    gi = _wrap(jnp.right_shift(growth * act, q), width)
    v_dyn = _wrap(_wrap(vmem - dv, width) + gi, width)

    in_ref = refcnt > 0
    v_new = jnp.where(in_ref, vmem, v_dyn)

    # SpkGen.
    spike = jnp.logical_and(v_new >= vth, jnp.logical_not(in_ref))

    # VmemSel: 4-way reset mux (Eq. 7).
    v_default = _wrap(v_new - _wrap(jnp.right_shift(decay * v_new, q), width), width)
    v_reset = jnp.where(
        mode == R.RESET_TO_ZERO,
        jnp.zeros_like(v_new),
        jnp.where(
            mode == R.RESET_BY_SUBTRACTION,
            _wrap(v_new - vth, width),
            jnp.where(mode == R.RESET_TO_CONSTANT, jnp.broadcast_to(vreset, v_new.shape), v_default),
        ),
    )

    spk_out_ref[...] = spike.astype(jnp.int32)
    vmem_out_ref[...] = jnp.where(spike, v_reset, v_new).astype(jnp.int32)
    refcnt_out_ref[...] = jnp.where(spike, refractory, jnp.maximum(refcnt - 1, 0)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("qspec", "block_n"))
def lif_layer_step(spikes_in, weights, vmem, refcnt, regs,
                   qspec: QSpec, block_n: int = DEFAULT_BLOCK_N):
    """One quantized spk_clk timestep of a layer via the Pallas kernel.

    Args:
      spikes_in: [M] int32 in {0,1} — pre-synaptic spike vector.
      weights:   [M, N] int32 — Qn.q raw synaptic weights (alpha*beta*omega
                 already folded in; zero where no connection).
      vmem:      [N] int32 — membrane potentials (Qn.q raw).
      refcnt:    [N] int32 — refractory countdowns.
      regs:      [NUM_REGS] int32 — control-register vector (see ref.py).
      qspec:     static quantization config.
      block_n:   neuron tile width.

    Returns: (spikes_out [N], vmem' [N], refcnt' [N]) int32.
    """
    m, n = weights.shape
    block_n = min(block_n, n)
    n_pad = (-n) % block_n
    if n_pad:
        # Padding lanes: zero weights, vmem 0, act 0 => never cross vth > 0.
        weights = jnp.pad(weights, ((0, 0), (0, n_pad)))
        vmem = jnp.pad(vmem, (0, n_pad))
        refcnt = jnp.pad(refcnt, (0, n_pad))
    n_t = n + n_pad
    grid = (n_t // block_n,)

    out_shapes = tuple(jax.ShapeDtypeStruct((n_t,), jnp.int32) for _ in range(3))
    lane = pl.BlockSpec((block_n,), lambda i: (i,))
    spk, vm, rc = pl.pallas_call(
        functools.partial(_lif_kernel, qspec=qspec),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m,), lambda i: (0,)),           # spike vector: broadcast
            pl.BlockSpec((m, block_n), lambda i: (0, i)),  # weight tile, VMEM-resident
            lane, lane,                                    # vmem / refcnt lanes
            pl.BlockSpec((R.NUM_REGS,), lambda i: (0,)),   # control registers
        ],
        out_specs=(lane, lane, lane),
        out_shape=out_shapes,
        interpret=True,
    )(spikes_in.astype(jnp.int32), weights, vmem, refcnt, regs)
    if n_pad:
        spk, vm, rc = spk[:n], vm[:n], rc[:n]
    return spk, vm, rc


def vmem_bytes(m: int, n: int, qspec: QSpec, block_n: int = DEFAULT_BLOCK_N) -> int:
    """Estimated VMEM working set of one kernel invocation (perf model).

    Weight tile [M, block_n] at ceil(W/8) bytes + state lanes + spike vector.
    Used by the §Perf analysis in EXPERIMENTS.md (interpret=True gives no
    real TPU residency data).
    """
    bn = min(block_n, n)
    wbytes = (qspec.width + 7) // 8
    return m * bn * wbytes + 3 * bn * 4 + m * 4 + R.NUM_REGS * 4
