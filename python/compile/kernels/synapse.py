"""Synaptic connectivity (Eq. 9) and polarity (Eq. 10) — CUBA synapses.

The paper factors every synaptic weight as  w_ij = alpha_ij * beta_ij * omega_ij:

  * alpha in {0,1} — the connection parameter (network topology): all-to-all,
    one-to-one, or gaussian (receptive-field / convolution-like, |i-j| <= r).
  * beta in {-1,+1} — the polarity parameter (excitatory vs inhibitory).
  * omega >= 0 — the absolute synaptic weight.

On our substrate the folded product w = alpha*beta*omega is what lives in the
layer's synaptic memory (exactly as in the hardware, where the signed Qn.q
word encodes polarity in the sign bit). These builders produce the alpha
masks; training learns signed weights directly and the masks are applied both
in the forward pass and to gradients (so pruned connections stay pruned),
mirroring the fact that absent alpha connections have no storage in hardware.

Mirrored in `rust/src/config/topology.rs` (bit-identical mask layout is
asserted by golden-vector tests).
"""

from __future__ import annotations

import numpy as np

ALL_TO_ALL = "all_to_all"
ONE_TO_ONE = "one_to_one"
GAUSSIAN = "gaussian"

TOPOLOGIES = (ALL_TO_ALL, ONE_TO_ONE, GAUSSIAN)


def connection_mask(m: int, n: int, topology: str, radius: int = 1) -> np.ndarray:
    """alpha_ij mask of shape [M, N] (pre-synaptic x post-synaptic), Eq. 9.

    * all_to_all: alpha = 1 everywhere                          (Eq. 9a)
    * one_to_one: alpha = 1 iff i == j (requires M == N)        (Eq. 9b)
    * gaussian:   alpha = 1 iff |i - j*M/N| <= radius — the receptive-field
      generalisation of Eq. 9c (the paper states |i-j| <= 1 for equal-width
      layers; for unequal widths the pre index is scaled, which is how a
      1-D convolution window maps onto the weight matrix).
    """
    if m <= 0 or n <= 0:
        raise ValueError(f"bad layer shape {m}x{n}")
    if topology == ALL_TO_ALL:
        return np.ones((m, n), dtype=np.int32)
    if topology == ONE_TO_ONE:
        if m != n:
            raise ValueError(f"one_to_one needs M == N, got {m} != {n}")
        return np.eye(m, dtype=np.int32)
    if topology == GAUSSIAN:
        if radius < 0:
            raise ValueError(f"gaussian radius must be >= 0, got {radius}")
        i = np.arange(m, dtype=np.float64)[:, None]
        centre = (np.arange(n, dtype=np.float64)[None, :] + 0.5) * m / n - 0.5
        return (np.abs(i - centre) <= radius + 1e-9).astype(np.int32)
    raise ValueError(f"unknown topology {topology!r}")


def synapse_count(m: int, n: int, topology: str, radius: int = 1) -> int:
    """Number of alpha=1 synapses — drives the resource/memory model."""
    return int(connection_mask(m, n, topology, radius).sum())


def fold_weights(omega: np.ndarray, alpha: np.ndarray, beta: np.ndarray) -> np.ndarray:
    """w = alpha * beta * omega (float domain; quantization happens later)."""
    if omega.shape != alpha.shape or omega.shape != beta.shape:
        raise ValueError("omega/alpha/beta shape mismatch")
    if not np.all(np.isin(alpha, (0, 1))):
        raise ValueError("alpha must be 0/1")
    if not np.all(np.isin(beta, (-1, 1))):
        raise ValueError("beta must be -1/+1")
    return alpha * beta * np.abs(omega)
