"""Pure-jnp oracles for the QUANTISENC LIF layer — the correctness reference.

Two references live here:

  * ``lif_layer_step_ref`` — the *bit-exact quantized* semantics of one
    spk_clk timestep of one hardware layer (ActGen + VmemDyn + VmemSel +
    SpkGen of paper Fig. 2), vectorised over the layer's N neurons. The
    Pallas kernel (`lif.py`) and the Rust cycle-accurate simulator
    (`rust/src/hdl/neuron.rs`) must match this exactly, bit for bit.

  * ``lif_layer_step_float`` — the double-precision LIF used as the
    "SNNTorch software" reference for RMSE/accuracy comparisons (paper
    Fig. 12 / Table VIII) and, with a surrogate gradient, for training.

Timestep semantics (one spk_clk edge, documented order — see DESIGN.md §2):

  1. ActGen:   act = wrap( sum_i spike_in[i] * w[i, j] )          (Eq. 6)
  2. If refractory counter > 0: hold vmem, decrement counter, no spike.
  3. VmemDyn:  v' = v - decay*v + growth*act      (wrapping Qn.q)  (Eq. 3)
  4. SpkGen:   spike = (v' >= vth)                                 (Fig. 2)
  5. VmemSel:  on spike, apply reset (Eq. 7) and arm the refractory
     counter with `refractory_period`.

Registers (paper Table I, dynamic configuration) are passed as a flat int32
vector so the same values can be programmed from the Rust coordinator's
control-register file:

  regs = [decay_raw, growth_raw, vth_raw, vreset_raw, reset_mode, refractory]

reset_mode: 0=default (exponential decay), 1=reset-to-zero,
            2=reset-by-subtraction, 3=reset-to-constant.
"""

from __future__ import annotations

import jax.numpy as jnp

from ..fixedpoint import QSpec

# Register vector layout (shared with rust/src/config/registers.rs).
REG_DECAY = 0
REG_GROWTH = 1
REG_VTH = 2
REG_VRESET = 3
REG_RESET_MODE = 4
REG_REFRACTORY = 5
NUM_REGS = 6

RESET_DEFAULT = 0
RESET_TO_ZERO = 1
RESET_BY_SUBTRACTION = 2
RESET_TO_CONSTANT = 3


def _wrap(x, width: int):
    half = 1 << (width - 1)
    mask = (1 << width) - 1
    return ((x + half) & mask) - half


def _fxmul(a, b, qspec: QSpec):
    # Full product fits int32 for W <= 16 (see fixedpoint.py docstring).
    return _wrap(jnp.right_shift(a * b, qspec.q), qspec.width)


def lif_layer_step_ref(spikes_in, weights, vmem, refcnt, regs, qspec: QSpec):
    """One quantized timestep of a layer. All int32; returns (spk, vmem', ref')."""
    spikes_in = jnp.asarray(spikes_in, jnp.int32)
    weights = jnp.asarray(weights, jnp.int32)
    vmem = jnp.asarray(vmem, jnp.int32)
    refcnt = jnp.asarray(refcnt, jnp.int32)
    regs = jnp.asarray(regs, jnp.int32)
    w = qspec.width

    decay = regs[REG_DECAY]
    growth = regs[REG_GROWTH]
    vth = regs[REG_VTH]
    vreset = regs[REG_VRESET]
    mode = regs[REG_RESET_MODE]
    refractory = regs[REG_REFRACTORY]

    # --- ActGen (Eq. 6): sequential wrapping adds == wrap of the exact sum,
    # because addition mod 2^W is associative. int32 accumulation is exact
    # for M <= 2^15 pre-synaptic connections at W <= 16.
    act = _wrap(jnp.dot(spikes_in, weights, preferred_element_type=jnp.int32), w)

    # --- VmemDyn (Eq. 3), wrapping Qn.q arithmetic.
    v_dyn = _wrap(_wrap(vmem - _fxmul(decay, vmem, qspec), w) + _fxmul(growth, act, qspec), w)

    in_refractory = refcnt > 0
    v_new = jnp.where(in_refractory, vmem, v_dyn)  # hold during refractory

    # --- SpkGen: threshold crossing; suppressed while refractory.
    spike = jnp.logical_and(v_new >= vth, jnp.logical_not(in_refractory))

    # --- VmemSel (Eq. 7): all four reset datapaths computed, mux'd by mode.
    v_default = _wrap(v_new - _fxmul(decay, v_new, qspec), w)
    v_zero = jnp.zeros_like(v_new)
    v_sub = _wrap(v_new - vth, w)
    v_const = jnp.broadcast_to(vreset, v_new.shape)
    v_reset = jnp.where(
        mode == RESET_TO_ZERO,
        v_zero,
        jnp.where(
            mode == RESET_BY_SUBTRACTION,
            v_sub,
            jnp.where(mode == RESET_TO_CONSTANT, v_const, v_default),
        ),
    )

    vmem_out = jnp.where(spike, v_reset, v_new)
    ref_out = jnp.where(spike, refractory, jnp.maximum(refcnt - 1, 0))
    return spike.astype(jnp.int32), vmem_out.astype(jnp.int32), ref_out.astype(jnp.int32)


def lif_layer_step_float(spikes_in, weights, vmem, refcnt, params):
    """Double-precision LIF step — the "software" (SNNTorch-like) reference.

    ``params`` is a dict with float leaves: decay, growth, vth, vreset,
    reset_mode (int), refractory (int). Mirrors the quantized datapath but
    without wrapping (floats don't overflow in this regime).
    """
    act = jnp.dot(spikes_in.astype(vmem.dtype), weights)
    v_dyn = vmem - params["decay"] * vmem + params["growth"] * act
    in_ref = refcnt > 0
    v_new = jnp.where(in_ref, vmem, v_dyn)
    spike = jnp.logical_and(v_new >= params["vth"], jnp.logical_not(in_ref))

    mode = params["reset_mode"]
    v_default = v_new - params["decay"] * v_new
    v_reset = jnp.where(
        mode == RESET_TO_ZERO,
        jnp.zeros_like(v_new),
        jnp.where(
            mode == RESET_BY_SUBTRACTION,
            v_new - params["vth"],
            jnp.where(mode == RESET_TO_CONSTANT, jnp.full_like(v_new, params["vreset"]), v_default),
        ),
    )
    vmem_out = jnp.where(spike, v_reset, v_new)
    ref_out = jnp.where(spike, params["refractory"], jnp.maximum(refcnt - 1, 0))
    return spike.astype(vmem.dtype), vmem_out, ref_out
