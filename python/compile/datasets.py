"""Synthetic spiking datasets — offline stand-ins for the paper's three sets.

The paper evaluates on Spiking MNIST (10 classes, 16x16 = 256 inputs after
the paper's own downscaling), DVS Gesture (11 classes, 400 inputs in their
configuration) and Spiking Heidelberg Digits (20 classes, 700 input
channels). None of those are redistributable inside this offline image, so —
per the substitution rule in DESIGN.md §1 — we generate *synthetic* spiking
datasets that match each set's input dimensionality, class count, encoding,
and temporal statistics, exercising exactly the same code paths (rate/latency
encoding → AER streaming → pipelined inference → spike-count decoding).

  * ``smnist``  — procedural 16x16 digit glyphs (7-segment-style strokes with
    per-sample jitter, thickness and noise), Poisson rate-encoded. This keeps
    the paper's headline property that digit 8 is structurally closest to
    3 and 0 (shared segments), so the Fig. 10/11 confusion structure holds.
  * ``dvs``     — 20x20 event grid, 11 motion "gestures": a Gaussian blob
    sweeping in 8 directions, 2 rotation senses, and a random-walk class.
  * ``shd``     — 700 channels, 20 classes: formant-like spectro-temporal
    ridge patterns (distinct channel trajectories per class) over T steps.

All generators are pure functions of (seed, split), mirrored bit-for-bit in
`rust/src/datasets/` via the same xorshift64* PRNG so the Rust request path
can stream identical test sets without Python.
"""

from __future__ import annotations

import numpy as np

# ---------------------------------------------------------------------------
# Deterministic PRNG shared with rust/src/datasets/rng.rs (xorshift64*).
# ---------------------------------------------------------------------------


class XorShift64Star:
    """xorshift64* — tiny, seedable, identical in Rust and Python."""

    MASK = (1 << 64) - 1

    def __init__(self, seed: int):
        self.state = (seed | 1) & self.MASK

    def next_u64(self) -> int:
        x = self.state
        x ^= (x >> 12)
        x ^= (x << 25) & self.MASK
        x ^= (x >> 27)
        self.state = x
        return (x * 0x2545F4914F6CDD1D) & self.MASK

    def uniform(self) -> float:
        """[0,1) with 53-bit resolution."""
        return (self.next_u64() >> 11) / float(1 << 53)

    def below(self, n: int) -> int:
        return self.next_u64() % n


# ---------------------------------------------------------------------------
# smnist: procedural digit glyphs on a 16x16 grid
# ---------------------------------------------------------------------------

# Seven-segment geometry on the 16x16 canvas; digit -> active segments.
# Segments: 0=top, 1=top-left, 2=top-right, 3=middle, 4=bot-left, 5=bot-right, 6=bottom
_SEGMENTS = {
    0: (0, 1, 2, 4, 5, 6),
    1: (2, 5),
    2: (0, 2, 3, 4, 6),
    3: (0, 2, 3, 5, 6),
    4: (1, 2, 3, 5),
    5: (0, 1, 3, 5, 6),
    6: (0, 1, 3, 4, 5, 6),
    7: (0, 2, 5),
    8: (0, 1, 2, 3, 4, 5, 6),
    9: (0, 1, 2, 3, 5, 6),
}

GRID = 16
SMNIST_INPUTS = GRID * GRID
SMNIST_CLASSES = 10
DVS_GRID = 20
DVS_INPUTS = DVS_GRID * DVS_GRID
DVS_CLASSES = 11
SHD_INPUTS = 700
SHD_CLASSES = 20


def _segment_cells(seg: int, dx: int, dy: int, thick: int):
    """Cells of one glyph segment, offset by (dx, dy), with thickness."""
    # Glyph occupies columns 4..12, rows 2..14 on the 16x16 canvas.
    x0, x1, ym, y0, y1 = 4, 11, 8, 2, 13
    cells = []
    if seg == 0:
        cells = [(x, y0) for x in range(x0, x1 + 1)]
    elif seg == 6:
        cells = [(x, y1) for x in range(x0, x1 + 1)]
    elif seg == 3:
        cells = [(x, ym) for x in range(x0, x1 + 1)]
    elif seg == 1:
        cells = [(x0, y) for y in range(y0, ym + 1)]
    elif seg == 2:
        cells = [(x1, y) for y in range(y0, ym + 1)]
    elif seg == 4:
        cells = [(x0, y) for y in range(ym, y1 + 1)]
    elif seg == 5:
        cells = [(x1, y) for y in range(ym, y1 + 1)]
    out = []
    for (x, y) in cells:
        for tx in range(thick):
            for ty in range(thick):
                out.append((x + dx + tx, y + dy + ty))
    return out


def digit_image(digit: int, rng: XorShift64Star) -> np.ndarray:
    """One jittered 16x16 intensity image in [0,1] for a digit class."""
    if not 0 <= digit <= 9:
        raise ValueError(f"digit out of range: {digit}")
    img = np.zeros((GRID, GRID), np.float64)
    dx = rng.below(5) - 2
    dy = rng.below(3) - 1
    thick = 1 + rng.below(2)
    for seg in _SEGMENTS[digit]:
        for (x, y) in _segment_cells(seg, dx, dy, thick):
            if 0 <= x < GRID and 0 <= y < GRID:
                img[y, x] = 0.75 + 0.25 * rng.uniform()
    # Pixel dropout + background noise make the task non-trivial.
    for i in range(GRID * GRID):
        if img.flat[i] > 0 and rng.uniform() < 0.08:
            img.flat[i] = 0.0
        elif img.flat[i] == 0 and rng.uniform() < 0.02:
            img.flat[i] = 0.3 * rng.uniform()
    return img


def rate_encode(image: np.ndarray, t_steps: int, rng: XorShift64Star,
                max_rate: float = 0.5) -> np.ndarray:
    """Poisson rate coding: spike[t, i] ~ Bernoulli(intensity_i * max_rate)."""
    flat = image.reshape(-1)
    spikes = np.zeros((t_steps, flat.size), np.int32)
    for t in range(t_steps):
        for i in range(flat.size):
            if flat[i] > 0 and rng.uniform() < flat[i] * max_rate:
                spikes[t, i] = 1
    return spikes


def smnist_sample(index: int, split: str, t_steps: int = 40, seed: int = 7):
    """(spikes [T,256], label) for sample `index` of a split."""
    base = 0x5EED_0000 + seed * 1_000_003 + (0 if split == "train" else 1 << 40)
    rng = XorShift64Star(base + index * 2_654_435_761)
    label = rng.below(SMNIST_CLASSES)
    img = digit_image(label, rng)
    return rate_encode(img, t_steps, rng), label


# ---------------------------------------------------------------------------
# dvs: moving-blob gestures on a 20x20 event grid
# ---------------------------------------------------------------------------


def dvs_sample(index: int, split: str, t_steps: int = 40, seed: int = 11):
    """(spikes [T,400], label) — 11 motion gesture classes."""
    base = 0xD4E5_0000 + seed * 1_000_003 + (0 if split == "train" else 1 << 40)
    rng = XorShift64Star(base + index * 2_654_435_761)
    label = rng.below(DVS_CLASSES)
    g = DVS_GRID
    spikes = np.zeros((t_steps, g * g), np.int32)
    cx, cy = g / 2 + rng.below(5) - 2, g / 2 + rng.below(5) - 2
    if label < 8:  # 8 linear sweep directions
        ang = 2 * np.pi * label / 8 + 0.2 * (rng.uniform() - 0.5)
        vx, vy = 0.45 * np.cos(ang), 0.45 * np.sin(ang)
        mode = "linear"
    elif label < 10:  # two rotation senses
        mode = "rotate"
        sense = 1.0 if label == 8 else -1.0
    else:  # random walk
        mode = "walk"
    x, y = cx, cy
    phase = 2 * np.pi * rng.uniform()
    for t in range(t_steps):
        if mode == "linear":
            x, y = (x + vx) % g, (y + vy) % g
        elif mode == "rotate":
            phase += sense * 0.35
            x = cx + 5.5 * np.cos(phase)
            y = cy + 5.5 * np.sin(phase)
        else:
            x = (x + (rng.uniform() - 0.5) * 3.0) % g
            y = (y + (rng.uniform() - 0.5) * 3.0) % g
        for i in range(g):
            for j in range(g):
                d2 = (i - y % g) ** 2 + (j - x % g) ** 2
                p = 0.9 * np.exp(-d2 / 3.0)
                if p > 0.02 and rng.uniform() < p:
                    spikes[t, i * g + j] = 1
    return spikes, label


# ---------------------------------------------------------------------------
# shd: spectro-temporal ridge patterns over 700 channels
# ---------------------------------------------------------------------------


def shd_sample(index: int, split: str, t_steps: int = 40, seed: int = 13):
    """(spikes [T,700], label) — 20 spoken-digit-like ridge classes."""
    base = 0x54D0_0000 + seed * 1_000_003 + (0 if split == "train" else 1 << 40)
    rng = XorShift64Star(base + index * 2_654_435_761)
    label = rng.below(SHD_CLASSES)
    spikes = np.zeros((t_steps, SHD_INPUTS), np.int32)
    # Each class = 3 deterministic formant trajectories (start chan, slope,
    # curvature derived from the label), plus per-sample jitter.
    for f in range(3):
        c0 = ((label * 131 + f * 197) % 17) * 40 + 10 + rng.below(8)
        slope = (((label * 31 + f * 7) % 9) - 4) * 3.0
        curve = (((label * 13 + f * 5) % 5) - 2) * 0.18
        for t in range(t_steps):
            centre = c0 + slope * t / t_steps * 8 + curve * (t - t_steps / 2) ** 2 / t_steps * 4
            for dc in range(-6, 7):
                ch = int(centre) + dc
                if 0 <= ch < SHD_INPUTS:
                    p = 0.75 * np.exp(-(dc * dc) / 6.0)
                    if rng.uniform() < p:
                        spikes[t, ch] = 1
    return spikes, label


# ---------------------------------------------------------------------------
# Batched helpers
# ---------------------------------------------------------------------------

SAMPLERS = {"smnist": smnist_sample, "dvs": dvs_sample, "shd": shd_sample}

INFO = {
    "smnist": dict(inputs=SMNIST_INPUTS, classes=SMNIST_CLASSES,
                   paper="Spiking MNIST [7]", train=60000, test=100),
    "dvs": dict(inputs=DVS_INPUTS, classes=DVS_CLASSES,
                paper="DVS Gesture [8]", train=1176, test=288),
    "shd": dict(inputs=SHD_INPUTS, classes=SHD_CLASSES,
                paper="Spiking Heidelberg Digit (SHD) [9]", train=8156, test=2264),
}


def batch(name: str, indices, split: str, t_steps: int = 40, seed: int | None = None):
    """Stack samples -> (spikes [B,T,N], labels [B])."""
    sampler = SAMPLERS[name]
    kwargs = {} if seed is None else {"seed": seed}
    xs, ys = [], []
    for i in indices:
        s, l = sampler(i, split, t_steps, **kwargs)
        xs.append(s)
        ys.append(l)
    return np.stack(xs), np.array(ys, np.int32)
