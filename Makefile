# Convenience targets; everything is plain cargo underneath.

.PHONY: all build test artifacts bench bench-norun bench-smoke bench-topology fmt clippy

all: build

build:
	cargo build --release

test:
	cargo test -q

# Regenerate the native artifact store (golden vectors + calibrated models).
artifacts:
	cargo run --release --bin repro -- artifacts

bench:
	cargo bench --bench bench_serving
	cargo bench --bench bench_pipeline

# Compile-check every bench target without running it (CI rot guard).
bench-norun:
	cargo bench --no-run

# Quick smoke: run the topology benches and emit BENCH_topology.json with
# per-topology storage words, synaptic ops/step, and step latency.
bench-topology:
	BENCH_TOPOLOGY_JSON=BENCH_topology.json cargo bench --bench bench_layer

bench-smoke: bench-topology

fmt:
	cargo fmt --all -- --check

clippy:
	cargo clippy --all-targets -- -D warnings
