# Convenience targets; everything is plain cargo underneath.

.PHONY: all build test artifacts bench bench-norun bench-smoke bench-topology bench-hotpath bench-serving snapshot-smoke chaos-smoke seu-smoke fmt clippy

all: build

build:
	cargo build --release

test:
	cargo test -q

# Regenerate the native artifact store (golden vectors + calibrated models).
artifacts:
	cargo run --release --bin repro -- artifacts

bench:
	cargo bench --bench bench_serving
	cargo bench --bench bench_pipeline

# Compile-check every bench target without running it (CI rot guard).
bench-norun:
	cargo bench --no-run

# Quick smoke: run the topology + hot-path benches and emit
# BENCH_topology.json (per-topology storage words, synaptic ops/step, step
# latency) and BENCH_hotpath.json (scalar-vs-packed layer step latency +
# serving-engine samples/s) in one bench_layer pass.
bench-topology:
	BENCH_TOPOLOGY_JSON=BENCH_topology.json BENCH_HOTPATH_JSON=BENCH_hotpath.json \
		cargo bench --bench bench_layer

# Merge serving-engine throughput into BENCH_hotpath.json and emit the
# lane-batched serving report (BENCH_batched.json).
bench-hotpath: bench-topology
	BENCH_HOTPATH_JSON=BENCH_hotpath.json BENCH_BATCHED_JSON=BENCH_batched.json \
		cargo bench --bench bench_serving

# Hermetic front-door SLO run: an in-process TCP server on an ephemeral
# port, open-loop Poisson load with in-band reconfigs, every network
# result verified bit-exactly against the sequential core. Emits
# BENCH_serving_slo.json (p50/p99 latency, samples/s, reject rate).
bench-serving:
	cargo run --release --bin repro -- loadgen \
		--sessions 2 --n 64 --rate 0 --reconfig-every 16 --pool 16 \
		--out BENCH_serving_slo.json

# bench-smoke runs everything above, then validates the reports (required
# keys present, >=5x topology ops reduction, >=3x packed layer-step
# speedup at N=400 / 2% firing, positive engine throughput, >=1.5x
# SIMD-vs-scalar lane-step speedup where a vector kernel is available,
# >=2x lane-64 serving samples/s with zero matrix-pool misses, and a
# clean oracle-verified front-door SLO report). A report file that was
# never generated is skipped with a warning, not an error.
bench-smoke: bench-hotpath bench-serving
	cargo run --release --bin repro -- bench-check \
		BENCH_topology.json BENCH_hotpath.json BENCH_batched.json \
		BENCH_serving_slo.json

# Snapshot/restore differential gate: freeze an engine after 8 samples to
# a versioned connectome image, revive it into a fresh engine, run to 16,
# and diff every result (and the final machine state) against an
# uninterrupted run — `repro restore` exits nonzero on any divergence.
snapshot-smoke:
	cargo run --release --bin repro -- snapshot \
		--n 8 --cores 2 --lanes 4 --out connectome_smoke.qcnx
	cargo run --release --bin repro -- restore \
		--in connectome_smoke.qcnx --total 16
	rm -f connectome_smoke.qcnx

# Self-healing differential gate: a hermetic TCP server under a seeded
# chaos schedule (shard-killing stage panics and channel drops with live
# retrying clients). Exits nonzero unless every surviving result is
# bit-identical to the sequential core, >=1 recovery ran, every shard ends
# Healthy, and recovery p99 is under BENCH_GATE_MAX_RECOVERY_MS (default
# 5s). Emits BENCH_chaos.json and re-validates it through bench-check.
chaos-smoke:
	cargo run --release --bin repro -- chaos-soak \
		--sessions 3 --n 48 --cores 2 --deaths 4 --ckpt-every 8 \
		--out BENCH_chaos.json
	cargo run --release --bin repro -- bench-check BENCH_chaos.json

# Memory-integrity differential gate: seeded single-event upsets against a
# SECDED Correct-mode engine (repaired in place, bit-exact vs the
# sequential core), a parity Detect-mode engine (quarantine + checkpoint
# rebuild + bit-exact resubmit), and a lane-64 scrub-overhead measurement.
# Exits nonzero unless every upset is accounted for (detection rate 1.0),
# at least one flip was corrected in place, no stream diverged, and the
# scrub overhead is under BENCH_GATE_MAX_SCRUB_OVERHEAD (default 10%).
# Emits BENCH_integrity.json and re-validates it through bench-check.
seu-smoke:
	cargo run --release --bin repro -- seu-soak \
		--cores 2 --flips 6 --det-flips 2 --n64 192 \
		--out BENCH_integrity.json
	cargo run --release --bin repro -- bench-check BENCH_integrity.json

fmt:
	cargo fmt --all -- --check

clippy:
	cargo clippy --all-targets -- -D warnings
