# Convenience targets; everything is plain cargo underneath.

.PHONY: all build test artifacts bench fmt clippy

all: build

build:
	cargo build --release

test:
	cargo test -q

# Regenerate the native artifact store (golden vectors + calibrated models).
artifacts:
	cargo run --release --bin repro -- artifacts

bench:
	cargo bench --bench bench_serving
	cargo bench --bench bench_pipeline

fmt:
	cargo fmt --all -- --check

clippy:
	cargo clippy --all-targets -- -D warnings
