//! Run-time reconfiguration — the paper's §VI-I headline: explore the
//! performance/power trade-off on an already-deployed core purely by
//! programming control registers (cfg_in), never touching the weights.
//!
//! This is the single-core view (one `hdl::Core` behind its register
//! file). For the same sweep on the *serving* path — one live
//! `ServingEngine` reprogrammed mid-stream through the epoch-tagged
//! control plane — see `examples/live_reconfig.rs`.
//!
//! ```bash
//! cargo run --release --example dynamic_reconfig
//! ```

use quantisenc::config::registers::{ResetMode, REG_REFRACTORY, REG_RESET_MODE};
use quantisenc::datasets::Dataset;
use quantisenc::experiments::{core_from_artifact, evaluate_core};
use quantisenc::hwmodel::power;
use quantisenc::runtime::artifacts::Manifest;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&quantisenc::golden::ensure_artifacts()?)?;
    let art = manifest.model("smnist", "Q5.3")?;
    println!("deployed core: smnist Q5.3 — sweeping dynamic registers (weights untouched)\n");
    println!(
        "{:32} {:>10} {:>9} {:>9}",
        "setting", "spikes/n", "accuracy", "power(W)"
    );

    let mut show = |label: &str, core: &mut quantisenc::hdl::Core| {
        let cfg = core.config().clone();
        let m = evaluate_core(core, Dataset::Smnist, 50, art.t_steps);
        let p = power::core_dynamic_w(&cfg, m.spike_rate, power::F0_HZ);
        println!(
            "{label:32} {:>10.1} {:>8.1}% {:>9.3}",
            m.spikes_per_neuron_150,
            100.0 * m.accuracy,
            p
        );
    };

    // R/C sweep (τ = 5 ms constant): growth falls with R.
    for (r, c) in [(500.0, 10.0), (100.0, 50.0), (50.0, 100.0), (10.0, 500.0)] {
        let (_, mut core) = core_from_artifact(&art)?;
        core.registers.set_rc(r, c)?;
        show(&format!("R={r:.0}MΩ C={c:.0}pF"), &mut core);
    }
    println!();

    // Reset mechanisms.
    for mode in [ResetMode::Default, ResetMode::BySubtraction, ResetMode::ToZero] {
        let (_, mut core) = core_from_artifact(&art)?;
        core.registers.write(REG_RESET_MODE, mode as i32)?;
        show(&format!("reset: {}", mode.label()), &mut core);
    }
    println!();

    // Refractory periods.
    for refr in [0, 2, 5] {
        let (_, mut core) = core_from_artifact(&art)?;
        core.registers.write(REG_REFRACTORY, refr)?;
        show(&format!("refractory = {refr} cycles"), &mut core);
    }

    println!("\nall of the above are cfg_in register writes on the same deployed core —");
    println!("the trade-off the paper exposes: fewer spikes => less power, at some accuracy cost");
    Ok(())
}
