//! Quickstart: build a small QUANTISENC core from scratch, program weights
//! and registers through the hardware-software interface, stream AER
//! spikes, and read the spike-counter output.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! No artifacts needed — this exercises the pure-Rust request path
//! (config → wt_in/cfg_in programming → AER spk_in → core → spk_out).

use quantisenc::config::registers::ResetMode;
use quantisenc::config::ModelConfig;
use quantisenc::coordinator::interface::Device;
use quantisenc::datasets::{Dataset, Split};
use quantisenc::fixed::Q5_3;
use quantisenc::hdl::aer;

fn main() -> anyhow::Result<()> {
    // 1. Static configuration (Table I): a 256x32x10 core at Q5.3, BRAM
    //    synaptic memory — the HDL-generation parameters.
    let config = ModelConfig::parse_arch("256x32x10", Q5_3)?;
    println!(
        "core {}: {} neurons, {} synapses, {}",
        config.arch_name(),
        config.total_neurons(),
        config.total_synapses(),
        config.qspec
    );
    let mut device = Device::new(config);

    // 2. wt_in: program synaptic weights (per-weight addressing). Here a
    //    hand-built feature detector: each hidden neuron pools an 8-pixel
    //    stripe; output neuron k sums hidden stripes with alternating sign.
    for h in 0..32usize {
        for p in 0..8usize {
            device.write_weight(0, h * 8 + p, h, Q5_3.from_float(0.5))?;
        }
    }
    for h in 0..32usize {
        for o in 0..10usize {
            let w = if (h + o) % 2 == 0 { 0.25 } else { -0.125 };
            device.write_weight(1, h, o, Q5_3.from_float(w))?;
        }
    }

    // 3. cfg_in: program the dynamic LIF registers at run time.
    device.configure(0.2, 1.0, 1.0, ResetMode::BySubtraction, 0)?;

    // 4. spk_in: stream a synthetic spiking-MNIST sample as AER events.
    let sample = Dataset::Smnist.sample(0, Split::Test, 20);
    let events = aer::encode(&sample.spikes, sample.t_steps, sample.inputs);
    println!("streaming {} AER events over {} timesteps", events.len(), sample.t_steps);

    let (result, out_events) = device.infer_aer(&events, sample.t_steps)?;

    // 5. spk_out: the spike-counter readout (paper Fig. 11).
    println!("output spike counts: {:?}", result.counts);
    println!("output AER events:   {}", out_events.len());
    println!(
        "activity: {} spikes total, {:.0}% of synaptic slots clock-gated",
        result.stats.spikes,
        100.0 * result.stats.gating_ratio()
    );
    println!("bus ledger: {:?}", device.bus());
    Ok(())
}
