//! Design-space exploration — the paper's §VI-D workflow: estimate
//! utilisation/power for candidate configurations *without synthesis*,
//! then find the largest wide/deep designs per board (Table IX).
//!
//! ```bash
//! cargo run --release --example design_explorer
//! ```

use quantisenc::config::{MemKind, ModelConfig};
use quantisenc::dse;
use quantisenc::fixed::{Q5_3, Q9_7};
use quantisenc::hwmodel::{power, resources, timing, Board};

fn main() -> anyhow::Result<()> {
    // 1. Point estimates for a few candidate architectures.
    println!("candidate estimates (Q5.3, BRAM), Virtex UltraScale:");
    let board = quantisenc::hwmodel::boards::VIRTEX_ULTRASCALE;
    for arch in ["256x128x10", "256x256x10", "400x300x300x11", "700x256x256x20"] {
        let (p, fits) = dse::estimate(arch, Q5_3, &board)?;
        println!(
            "  {arch:>16}: {:>7.0} LUT {:>6.0} FF {:>6.1} BRAM  {:>6.3} W  {}",
            p.resources.luts,
            p.resources.ffs,
            p.resources.brams,
            p.power_w,
            if fits { "fits" } else { "too big" }
        );
    }

    // 2. Quantization trade-off at a fixed architecture.
    println!("\nquantization trade-off (256x128x10):");
    for q in [Q5_3, Q9_7] {
        let cfg = ModelConfig::parse_arch("256x128x10", q)?;
        let r = resources::core(&cfg);
        let p = power::core_dynamic_w(&cfg, power::RATE0, power::F0_HZ);
        println!(
            "  {q}: {:>7.0} LUT {:>6.0} FF {:>4.0} DSP  {:.3} W",
            r.luts, r.ffs, r.dsps, p
        );
    }

    // 3. Memory-fabric trade-off (Fig. 13): frequency vs power.
    println!("\nmemory fabric (256x128x10 @ Q5.3):");
    for mem in MemKind::all() {
        let cfg = ModelConfig::parse_arch("256x128x10", Q5_3)?.with_mem(mem);
        let fpeak = timing::peak_frequency_hz(mem);
        let p = power::core_dynamic_w(&cfg, power::RATE0, power::F0_HZ);
        println!(
            "  {:8}: peak {:>4.0} kHz, {:>6.3} W @600 kHz{}",
            mem.label(),
            fpeak / 1e3,
            p,
            if timing::meets_timing(mem, 600e3) { "" } else { "  (violates 600 kHz!)" }
        );
    }

    // 4. Table IX: largest wide/deep design per board.
    println!("\nlargest configurations per board (Table IX):");
    for board in Board::all() {
        let wide = dse::largest_wide(&board, 256, 10, Q5_3).unwrap();
        let deep = dse::largest_deep(&board, 256, 10, 64, Q5_3).unwrap();
        println!(
            "  {:18} wide 256-{}-10 ({:.3} W)   deep 256-{}(64)-10 ({:.3} W)",
            board.name,
            wide.config.sizes()[1],
            wide.power_w,
            deep.config.num_layers() - 1,
            deep.power_w
        );
    }
    Ok(())
}
