//! Live reconfiguration — reprogram a *serving* engine mid-stream.
//!
//! The paper's software-defined claim (§II, §VI-I): LIF dynamics and
//! weights are reprogrammed at run time through cfg_in/wt_in on the
//! deployed core. This driver shows it on the production request path:
//! one `ServingEngine` is deployed once and then taken through several
//! operating points **without draining traffic** — reconfigurations are
//! scheduled in-band between samples of one request session, every result
//! reports the config epoch it was computed under, and the cfg_in beats
//! show up on the same AXI ledger as the spike traffic.
//!
//! ```bash
//! cargo run --release --example live_reconfig [n_per_epoch] [cores]
//! ```

use quantisenc::config::registers::{ResetMode, REG_REFRACTORY};
use quantisenc::coordinator::control::ReconfigProgram;
use quantisenc::coordinator::serving::{ServingOptions, SessionOp};
use quantisenc::datasets::{Dataset, Split};
use quantisenc::experiments::engine_from_artifact;
use quantisenc::hwmodel::power;
use quantisenc::runtime::artifacts::Manifest;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args().nth(1).map(|s| s.parse()).transpose()?.unwrap_or(40);
    let cores: usize = std::env::args().nth(2).map(|s| s.parse()).transpose()?.unwrap_or(2);

    let manifest = Manifest::load(&quantisenc::golden::ensure_artifacts()?)?;
    let art = manifest.model("smnist", "Q5.3")?;
    let (cfg, mut engine) = engine_from_artifact(&art, ServingOptions::with_cores(cores))?;
    let control = engine.control_plane();
    let baseline = control.registers();
    println!(
        "deployed: smnist {} Q5.3 on {} shards — one engine for the whole run\n",
        cfg.arch_name(),
        engine.num_cores()
    );

    // The operating points to visit, each as an absolute cfg_in program
    // (baseline + one knob), applied live between samples.
    let mut points: Vec<(String, ReconfigProgram)> = Vec::new();
    for (r, c) in [(100.0, 50.0), (50.0, 100.0)] {
        let mut regs = baseline.clone();
        regs.set_rc(r, c)?;
        points.push((format!("R={r:.0}MΩ C={c:.0}pF"), ReconfigProgram::from_registers(&regs)));
    }
    let mut regs = baseline.clone();
    regs.set_reset_mode(ResetMode::ToZero)?;
    points.push(("reset-to-zero".into(), ReconfigProgram::from_registers(&regs)));
    let mut regs = baseline.clone();
    regs.write(REG_REFRACTORY, 5)?;
    points.push(("refractory=5".into(), ReconfigProgram::from_registers(&regs)));

    // One request session: n samples at the deployment config, then for
    // each operating point an in-band reconfig followed by n more samples.
    let total = n * (points.len() + 1);
    let samples: Vec<_> =
        (0..total as u64).map(|i| Dataset::Smnist.sample(i, Split::Test, art.t_steps)).collect();
    let mut labels = vec!["baseline (deployment regs)".to_string()];
    let mut ops: Vec<SessionOp> = samples[..n].iter().map(SessionOp::Submit).collect();
    for (i, (label, program)) in points.into_iter().enumerate() {
        ops.push(SessionOp::Reconfig(program));
        ops.extend(samples[(i + 1) * n..(i + 2) * n].iter().map(SessionOp::Submit));
        labels.push(label);
    }

    let results = engine.run_session(&ops)?;

    // Group by the epoch each result reports and summarise per config.
    println!(
        "{:32} {:>6} {:>10} {:>9} {:>9}",
        "epoch / setting", "n", "spikes/n", "accuracy", "power(W)"
    );
    for (epoch, label) in labels.iter().enumerate() {
        let mine: Vec<_> = results.iter().filter(|r| r.epoch == epoch as u64).collect();
        let mut stats = quantisenc::hdl::ActivityStats::default();
        let mut correct = 0usize;
        for r in &mine {
            stats.add(&r.stats);
            if r.prediction == samples[r.stream_id].label {
                correct += 1;
            }
        }
        let p = power::core_dynamic_w(&cfg, stats.spike_rate(), power::F0_HZ);
        println!(
            "{:>2} {label:29} {:>6} {:>10.1} {:>8.1}% {:>9.3}",
            epoch,
            mine.len(),
            stats.spike_rate() * 150.0,
            100.0 * correct as f64 / mine.len().max(1) as f64,
            p
        );
    }

    let bus = engine.bus();
    println!(
        "\nAXI ledger: {} beats total — cfg_in {} (reprogramming × {} shards), wt_in {}, \
         spk_in {}, spk_out {}",
        bus.beats(),
        bus.cfg_writes,
        engine.num_cores(),
        bus.wt_writes,
        bus.spk_in_events,
        bus.spk_out_events
    );
    println!(
        "{} config epochs served by one engine, zero rebuilds — \
         the paper's software-defined claim on the serving path",
        engine.epoch() + 1
    );
    Ok(())
}
