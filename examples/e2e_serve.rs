//! End-to-end driver — the full native stack on a real workload.
//!
//! Pipeline proven here:
//!
//!   1. artifact bootstrap (pure Rust, no Python): the native calibrator in
//!      `quantisenc::golden` synthesizes matched-filter weights from the
//!      synthetic spiking-MNIST generator, fits the ridge readout, quantizes
//!      to Qn.q, and writes the manifest + weight files;
//!   2. this binary serves batched requests through the unified
//!      `ServingEngine` (C sharded cores × per-layer pipelined stages with
//!      bounded channels) and reports accuracy + latency/throughput;
//!   3. cross-checks the engine's results bit-for-bit against the
//!      sequential cycle-accurate `hdl::Core`, and reports modelled
//!      hardware power from the measured spike activity.
//!
//! ```bash
//! cargo run --release --example e2e_serve [n_requests] [cores]
//! ```

use std::time::Instant;

use quantisenc::coordinator::serving::{ServingEngine, ServingOptions};
use quantisenc::datasets::{Dataset, Split};
use quantisenc::experiments;
use quantisenc::hwmodel::power;
use quantisenc::runtime::artifacts::Manifest;

fn main() -> anyhow::Result<()> {
    let n: u64 = std::env::args().nth(1).map(|s| s.parse()).transpose()?.unwrap_or(200);
    let cores: usize = std::env::args().nth(2).map(|s| s.parse()).transpose()?.unwrap_or(4);

    // --- Bootstrap + load the artifact store (generated natively on first run).
    let manifest = Manifest::load(&quantisenc::golden::ensure_artifacts()?)?;
    let art = manifest.model("smnist", "Q5.3")?;
    println!(
        "model: smnist {} {} (float reference accuracy: {:.1}%)",
        art.sizes.iter().map(|s| s.to_string()).collect::<Vec<_>>().join("x"),
        art.qname,
        100.0 * art.float_acc
    );

    // --- Serve the batch through the ServingEngine.
    let (config, core) = experiments::core_from_artifact(&art)?;
    let mut engine = ServingEngine::new(
        &config,
        &art.weights,
        &core.registers,
        ServingOptions::with_cores(cores),
    )?;
    let samples: Vec<_> =
        (0..n).map(|i| Dataset::Smnist.sample(i, Split::Test, art.t_steps)).collect();

    let t0 = Instant::now();
    let results = engine.run_batch(&samples)?;
    let wall = t0.elapsed();
    let correct = results.iter().zip(&samples).filter(|(r, s)| r.prediction == s.label).count();
    // The engine is a batch API, so only batch-level wall clock is honest
    // here; per-request latency percentiles belong to the per-request paths
    // (`repro serve --multicore` records them via Telemetry).
    println!(
        "serving-engine ({} cores): {} requests in {:.2?} ({:.1}/s), accuracy {:.1}%",
        engine.num_cores(),
        results.len(),
        wall,
        results.len() as f64 / wall.as_secs_f64(),
        100.0 * correct as f64 / results.len().max(1) as f64
    );

    // --- Cross-check a subset on the sequential cycle-accurate core
    //     (bit-exactness) and extract activity for the hardware power model.
    let (_, mut seq_core) = experiments::core_from_artifact(&art)?;
    let mut stats = quantisenc::hdl::ActivityStats::default();
    let check = 20.min(samples.len());
    for (i, sample) in samples.iter().take(check).enumerate() {
        let r = seq_core.run(sample);
        anyhow::ensure!(
            r.counts == results[i].counts,
            "sample {i}: sequential {:?} != engine {:?}",
            r.counts,
            results[i].counts
        );
        anyhow::ensure!(r.prediction == results[i].prediction, "sample {i}: prediction diverged");
        stats.add(&r.stats);
    }
    println!("hdl cross-check: {check}/{check} samples bit-exact with the sequential core");
    println!(
        "measured activity: {:.3} spikes/neuron/step, {:.0}% synaptic slots gated",
        stats.spike_rate(),
        100.0 * stats.gating_ratio()
    );
    let p = power::core_dynamic_w(&config, stats.spike_rate(), power::F0_HZ);
    let (f_peak, ppw) = power::peak_perf_per_watt(&config, stats.spike_rate());
    println!(
        "hardware model @600 kHz: {:.3} W dynamic; peak {:.1} GOPS/W at {:.0} kHz",
        p,
        ppw,
        f_peak / 1e3
    );
    Ok(())
}
