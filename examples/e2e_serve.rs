//! End-to-end driver — the full three-layer stack on a real workload.
//!
//! Pipeline proven here (run recorded in EXPERIMENTS.md):
//!
//!   1. build-time (already done by `make artifacts`): JAX trains the SNN
//!      with surrogate gradients on the synthetic spiking-MNIST set (loss
//!      curve in artifacts/train_log_smnist.json), quantizes the weights to
//!      Qn.q, lowers the Pallas-kernel forward to HLO text;
//!   2. this binary (pure Rust, no Python): loads the artifact, compiles it
//!      on the PJRT CPU client, serves batched requests, reports accuracy +
//!      latency/throughput;
//!   3. cross-checks the PJRT results bit-for-bit against the
//!      cycle-accurate hdl core, and reports modelled hardware power from
//!      the measured spike activity.
//!
//! ```bash
//! cargo run --release --example e2e_serve [n_requests]
//! ```

use std::time::Instant;

use quantisenc::coordinator::metrics::Telemetry;
use quantisenc::datasets::{Dataset, Split};
use quantisenc::experiments;
use quantisenc::hwmodel::power;
use quantisenc::runtime::{artifacts::Manifest, Runtime};
use quantisenc::util::json::Json;

fn main() -> anyhow::Result<()> {
    let n: u64 = std::env::args().nth(1).map(|s| s.parse()).transpose()?.unwrap_or(200);

    // --- Load the AOT artifact (trained + lowered at build time).
    let manifest = Manifest::load(&quantisenc::artifacts_dir())?;
    let art = manifest.model("smnist", "Q5.3")?;
    println!(
        "model: smnist {} {} (float acc at train time: {:.1}%)",
        art.sizes.iter().map(|s| s.to_string()).collect::<Vec<_>>().join("x"),
        art.qname,
        100.0 * art.float_acc
    );
    // Show the training loss curve (logged by the L2 trainer).
    if let Ok(log) = manifest.golden("train_log_smnist.json") {
        if let (Some(losses), Some(accs)) = (log.get("loss"), log.get("eval_acc")) {
            let l = losses.num_vec().unwrap_or_default();
            let a = accs.num_vec().unwrap_or_default();
            println!(
                "training: {} steps, loss {:.3} -> {:.3}, eval acc {:?}",
                l.len(),
                l.first().unwrap_or(&0.0),
                l.last().unwrap_or(&0.0),
                a.iter().map(|x| format!("{:.1}%", 100.0 * x)).collect::<Vec<_>>()
            );
        }
        let _ = Json::Null; // (silence unused-import paths on older rustc)
    }

    // --- Serve over the PJRT request path.
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let exe = rt.load_model(&art)?;

    let mut tel = Telemetry::new();
    tel.start();
    let mut predictions = Vec::with_capacity(n as usize);
    for i in 0..n {
        let s = Dataset::Smnist.sample(i, Split::Test, art.t_steps);
        let t0 = Instant::now();
        let out = exe.run(&s.spikes)?;
        tel.record(t0.elapsed(), &Default::default(), Some(out.prediction == s.label));
        predictions.push(out);
    }
    tel.stop();
    println!("PJRT serving: {}", tel.summary());

    // --- Cross-check a subset on the cycle-accurate core (bit-exactness)
    //     and extract activity for the hardware power model.
    let (config, mut core) = experiments::core_from_artifact(&art)?;
    let mut stats = quantisenc::hdl::ActivityStats::default();
    for i in 0..20u64 {
        let s = Dataset::Smnist.sample(i, Split::Test, art.t_steps);
        let r = core.run(&s);
        let pjrt_counts: Vec<u32> = predictions[i as usize].counts.iter().map(|&c| c as u32).collect();
        anyhow::ensure!(
            r.counts == pjrt_counts,
            "sample {i}: hdl {:?} != pjrt {:?}",
            r.counts,
            pjrt_counts
        );
        stats.add(&r.stats);
    }
    println!("hdl cross-check: 20/20 samples bit-exact with the PJRT path");
    println!(
        "measured activity: {:.3} spikes/neuron/step, {:.0}% synaptic slots gated",
        stats.spike_rate(),
        100.0 * stats.gating_ratio()
    );
    let p = power::core_dynamic_w(&config, stats.spike_rate(), power::F0_HZ);
    let (f_peak, ppw) = power::peak_perf_per_watt(&config, stats.spike_rate());
    println!(
        "hardware model @600 kHz: {:.3} W dynamic; peak {:.1} GOPS/W at {:.0} kHz",
        p,
        ppw,
        f_peak / 1e3
    );
    Ok(())
}
