//! Pipelined streaming throughput — Fig. 8 / §VI-G.
//!
//! Runs the same stream batch (a) sequentially through one core, and
//! (b) through the thread-per-layer pipelined executor, asserting
//! bit-identical results, then prints the analytic Fig.-8 schedule numbers
//! (41.67 fps pipelined vs 31.25 fps dataflow [30]).
//!
//! ```bash
//! cargo run --release --example pipeline_throughput [n_streams]
//! ```

use std::time::Instant;

use quantisenc::baselines::DataflowBaseline;
use quantisenc::coordinator::pipeline::{run_pipelined, ScheduleModel};
use quantisenc::datasets::{Dataset, Split};
use quantisenc::experiments::core_from_artifact;
use quantisenc::runtime::artifacts::Manifest;

fn main() -> anyhow::Result<()> {
    let n: u64 = std::env::args().nth(1).map(|s| s.parse()).transpose()?.unwrap_or(64);
    let manifest = Manifest::load(&quantisenc::golden::ensure_artifacts()?)?;
    let art = manifest.model("smnist", "Q5.3")?;
    let (config, mut core) = core_from_artifact(&art)?;
    let samples: Vec<_> =
        (0..n).map(|i| Dataset::Smnist.sample(i, Split::Test, art.t_steps)).collect();

    // Sequential (dataflow) execution.
    let t0 = Instant::now();
    let seq: Vec<_> = samples.iter().map(|s| core.run(s)).collect();
    let dt_seq = t0.elapsed();

    // Pipelined execution (thread per layer, bounded channels).
    let t0 = Instant::now();
    let piped = run_pipelined(&config, &art.weights, &core.registers, &samples)?;
    let dt_pipe = t0.elapsed();

    for (i, (p, s)) in piped.iter().zip(&seq).enumerate() {
        anyhow::ensure!(p.counts == s.counts, "stream {i} diverged");
    }
    println!("correctness: {n} pipelined streams bit-exact with sequential execution");
    println!(
        "wall-clock:  sequential {dt_seq:?} ({:.1}/s)   pipelined {dt_pipe:?} ({:.1}/s)",
        n as f64 / dt_seq.as_secs_f64(),
        n as f64 / dt_pipe.as_secs_f64(),
    );
    println!("             (wall-clock overlap needs >1 host core; the hardware claim is the cycle model below)");

    // The paper's hardware throughput claim (Eq. 11 vs [30]).
    let m = ScheduleModel::paper_baseline();
    let baseline = DataflowBaseline::new(config);
    println!("\nFig. 8 schedule model (exposure 20 ms, N_reset 4 @ 1 kHz, K = 3):");
    println!("  pipelined:  {:.2} fps   (paper: 41.67)", m.pipelined_fps());
    println!(
        "  dataflow:   {:.2} fps   (paper: 31.25, Gyro [30])",
        baseline.fps(m.exposure_s, m.f_hz)
    );
    println!("  improvement: {:.1}%  (paper: 33.3%)", 100.0 * (m.speedup() - 1.0));
    println!(
        "  initiation interval {:.1} ms, pipeline fill {:.1} ms",
        1e3 * m.initiation_interval_s(),
        1e3 * m.fill_latency_s()
    );
    Ok(())
}
